//! Hardware target registry — name → [`VtaConfig`], the hardware axis of
//! the tuning problem.
//!
//! The paper's premise is that the *hardware* shapes both landscapes the
//! multi-level models learn: the extended-VTA ZCU102 and TVM's stock
//! ZCU104 preset differ only in buffer capacities, and that alone moves
//! the invalid-config boundary (§A.1/§A.2). The registry makes the
//! target a first-class, name-routed axis — like `--network` for
//! workloads and `--space` for knob sets — so `tune`, `tune-net`,
//! `simulate`, the experiment harnesses, and the fleet scheduler
//! ([`crate::engine::FleetTuner`]) all select hardware the same way.
//!
//! [`TargetMeta`] is the capacity-defining subset of a config that gets
//! stamped into tuning logs (the hardware analogue of
//! [`crate::tuner::database::LayerMeta`]): it is what lets
//! [`crate::tuner::database::TransferDb`] compute a hardware distance
//! between a stored log and a new run and down-weight cross-target
//! transfer accordingly (cf. the HW-Aware Initialization and MetaTune
//! lines in PAPERS.md).

use super::config::VtaConfig;
use crate::util::json::Json;

/// Registered target names: the paper's default first, then the other
/// design points. Listing order is presentational only — the order
/// [`crate::engine::FleetTuner`] visits targets in is derived from the
/// configs' capacities ([`capacity_score`]), not from this array.
pub const TARGET_NAMES: [&str; 4] =
    ["zcu102", "zcu104", "edge-small", "hiband"];

/// Look up a registered target by name.
pub fn target(name: &str) -> Option<VtaConfig> {
    match name {
        "zcu102" => Some(VtaConfig::zcu102()),
        "zcu104" => Some(VtaConfig::zcu104()),
        "edge-small" => Some(VtaConfig::edge_small()),
        "hiband" => Some(VtaConfig::hiband()),
        _ => None,
    }
}

/// All registered targets, in [`TARGET_NAMES`] order.
pub fn all() -> Vec<VtaConfig> {
    TARGET_NAMES.iter().map(|n| target(n).unwrap()).collect()
}

/// Capacity-ordering key: total scratchpad log-size first, DMA width as
/// the tiebreak (a lexicographic tuple, not a packed scalar — packing
/// would silently misorder a future custom target with a huge DMA
/// width). The fleet scheduler tunes the smallest target first so its
/// (cheap, conservative) logs seed the bigger targets' warm starts.
pub fn capacity_score(cfg: &VtaConfig) -> (u64, u64) {
    let logs = (cfg.log_inp_buff_size
        + cfg.log_wgt_buff_size
        + cfg.log_acc_buff_size
        + cfg.log_uop_buff_size) as u64;
    (logs, cfg.dma_bytes_per_cycle)
}

/// The capacity-defining fields of a target, as persisted in tuning logs
/// (`"target"` object) and consumed by the transfer store's hardware
/// distance. Mirrors the [`VtaConfig`] fields that move the validity
/// boundary (buffer log-sizes, block/batch geometry) plus the DMA stream
/// width (the dominant throughput knob of the cycle model).
#[derive(Clone, Debug, PartialEq)]
pub struct TargetMeta {
    /// Registered target name (identity only, not geometry).
    pub name: String,
    /// log2 uop-buffer bytes.
    pub log_uop_buff_size: u32,
    /// log2 input scratchpad bytes.
    pub log_inp_buff_size: u32,
    /// log2 weight scratchpad bytes.
    pub log_wgt_buff_size: u32,
    /// log2 accumulator scratchpad bytes.
    pub log_acc_buff_size: u32,
    /// log2 GEMM batch dimension.
    pub log_batch: u32,
    /// log2 GEMM block dimension.
    pub log_block: u32,
    /// DMA stream width (bytes per cycle).
    pub dma_bytes_per_cycle: u64,
}

impl TargetMeta {
    /// Extract the capacity fields of a full config.
    pub fn of(cfg: &VtaConfig) -> TargetMeta {
        TargetMeta {
            name: cfg.target.clone(),
            log_uop_buff_size: cfg.log_uop_buff_size,
            log_inp_buff_size: cfg.log_inp_buff_size,
            log_wgt_buff_size: cfg.log_wgt_buff_size,
            log_acc_buff_size: cfg.log_acc_buff_size,
            log_batch: cfg.log_batch,
            log_block: cfg.log_block,
            dma_bytes_per_cycle: cfg.dma_bytes_per_cycle,
        }
    }

    /// Log-space capacity signature (the name is identity, not
    /// geometry, and stays out).
    fn signature(&self) -> [f64; 7] {
        [
            self.log_inp_buff_size as f64,
            self.log_wgt_buff_size as f64,
            self.log_acc_buff_size as f64,
            self.log_uop_buff_size as f64,
            self.log_batch as f64,
            self.log_block as f64,
            (self.dma_bytes_per_cycle.max(1) as f64).log2(),
        ]
    }

    /// Hardware similarity in `(0, 1]`: 1 for capacity-identical
    /// targets, decaying with the Euclidean distance between log-space
    /// capacity signatures (one log2 step on every buffer — the
    /// zcu102↔zcu104 gap — lands at 1/3). Same decay shape as
    /// [`crate::tuner::database::LayerMeta::similarity`], so the two
    /// distances compose multiplicatively in the transfer store.
    pub fn hw_similarity(&self, other: &TargetMeta) -> f64 {
        let (a, b) = (self.signature(), other.signature());
        let d2: f64 =
            a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
        1.0 / (1.0 + d2.sqrt())
    }

    /// Same capacity fields (names may differ — equality of geometry is
    /// what decides whether a transferred validity label needs the
    /// capacity audit).
    pub fn same_capacities(&self, other: &TargetMeta) -> bool {
        self.signature() == other.signature()
    }

    /// Stable text key over the capacity fields (name excluded): two
    /// targets share a key iff [`TargetMeta::same_capacities`] holds.
    /// The meta-training corpus buckets model-V ensembles under this key
    /// — validity is a hard function of buffer geometry, so a V trained
    /// on one capacity class must never serve another.
    pub fn capacity_key(&self) -> String {
        format!(
            "i{}w{}a{}u{}b{}k{}d{}",
            self.log_inp_buff_size,
            self.log_wgt_buff_size,
            self.log_acc_buff_size,
            self.log_uop_buff_size,
            self.log_batch,
            self.log_block,
            self.dma_bytes_per_cycle
        )
    }

    /// Serialize as the tuning-log `"target"` object.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", self.name.as_str())
            .set("log_uop_buff_size", self.log_uop_buff_size as usize)
            .set("log_inp_buff_size", self.log_inp_buff_size as usize)
            .set("log_wgt_buff_size", self.log_wgt_buff_size as usize)
            .set("log_acc_buff_size", self.log_acc_buff_size as usize)
            .set("log_batch", self.log_batch as usize)
            .set("log_block", self.log_block as usize)
            .set("dma_bytes_per_cycle", self.dma_bytes_per_cycle);
        o
    }

    /// Parse a tuning-log `"target"` object; `None` on missing fields.
    pub fn from_json(j: &Json) -> Option<TargetMeta> {
        let geti = |k: &str| {
            j.get(k).and_then(Json::as_usize).map(|v| v as u32)
        };
        Some(TargetMeta {
            name: j.get("name").and_then(Json::as_str)?.to_string(),
            log_uop_buff_size: geti("log_uop_buff_size")?,
            log_inp_buff_size: geti("log_inp_buff_size")?,
            log_wgt_buff_size: geti("log_wgt_buff_size")?,
            log_acc_buff_size: geti("log_acc_buff_size")?,
            log_batch: geti("log_batch")?,
            log_block: geti("log_block")?,
            dma_bytes_per_cycle: j
                .get("dma_bytes_per_cycle")
                .and_then(Json::as_i64)? as u64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_every_listed_name() {
        for name in TARGET_NAMES {
            let cfg = target(name).unwrap_or_else(|| {
                panic!("registered target '{name}' must resolve")
            });
            assert_eq!(cfg.target, name);
        }
        assert!(target("zcu999").is_none());
        assert_eq!(all().len(), TARGET_NAMES.len());
    }

    #[test]
    fn capacity_score_orders_small_to_large() {
        let score = |n: &str| capacity_score(&target(n).unwrap());
        assert!(score("edge-small") < score("zcu104"));
        assert!(score("zcu104") < score("zcu102"));
        assert!(score("zcu102") < score("hiband"));
    }

    #[test]
    fn hw_similarity_identity_and_ordering() {
        let m = |n: &str| TargetMeta::of(&target(n).unwrap());
        let z102 = m("zcu102");
        assert_eq!(z102.hw_similarity(&z102), 1.0);
        // one log2 step on all four buffers: dist 2 → 1/3 exactly
        let s104 = z102.hw_similarity(&m("zcu104"));
        assert!((s104 - 1.0 / 3.0).abs() < 1e-12);
        // edge-small is two steps + a DMA halving away: strictly farther
        assert!(z102.hw_similarity(&m("edge-small")) < s104);
        // hiband shares every buffer but uop: closer than zcu104
        assert!(z102.hw_similarity(&m("hiband")) > s104);
    }

    #[test]
    fn same_capacities_ignores_name() {
        let a = TargetMeta::of(&target("zcu102").unwrap());
        let mut b = a.clone();
        b.name = "custom-clone".to_string();
        assert!(a.same_capacities(&b));
        assert_ne!(a, b, "PartialEq still sees the name");
        let c = TargetMeta::of(&target("zcu104").unwrap());
        assert!(!a.same_capacities(&c));
    }

    #[test]
    fn capacity_key_tracks_same_capacities() {
        let a = TargetMeta::of(&target("zcu102").unwrap());
        let mut clone = a.clone();
        clone.name = "custom-clone".to_string();
        assert_eq!(a.capacity_key(), clone.capacity_key(),
                   "key ignores the name");
        for name in ["zcu104", "edge-small", "hiband"] {
            let other = TargetMeta::of(&target(name).unwrap());
            assert_ne!(a.capacity_key(), other.capacity_key());
        }
    }

    #[test]
    fn target_meta_json_round_trip() {
        for name in TARGET_NAMES {
            let meta = TargetMeta::of(&target(name).unwrap());
            let back = TargetMeta::from_json(&meta.to_json()).unwrap();
            assert_eq!(back, meta);
        }
        assert!(TargetMeta::from_json(&Json::obj()).is_none());
    }
}
