//! Extended-VTA accelerator substrate (paper Appendix A.1).
//!
//! The paper profiles configurations on an extended VTA [32] implemented on a
//! Xilinx ZCU102; we reproduce the *mechanisms that shape the tuning problem*
//! in a simulator (ARCHITECTURE.md §Substitutions):
//!
//! * [`config`] — the Table 1 hardware parameters (buffer sizes, block
//!   geometry, data widths) plus the timing coefficients of the cycle model.
//! * [`targets`] — the name → config registry (`--target`): the Table-1
//!   design points plus the edge-small/hiband capacity variants, and the
//!   [`targets::TargetMeta`] stamp tuning logs carry for cross-target
//!   transfer.
//! * [`isa`] — the instruction stream the backend compiler emits: 2-D DMA
//!   loads/stores, memsets, uop-programmed GEMM with two hardware loops, the
//!   requantizing ALU, and the 4 dependency-token flags VTA uses to overlap
//!   its load / compute / store modules.
//! * [`layout`] — DRAM packing helpers (raw image → input vectors, HWIO
//!   weights → 16×16 GEMM blocks, output vectors → HWC tensor).
//! * [`functional`] — numeric execution over int8/int32 with the **fault
//!   model**: out-of-range INP/WGT/UOP addressing raises a register error
//!   (crash; on the real board this required a manual reboot), while ACC and
//!   cross-thread aliasing *wraps silently* and corrupts the output — the two
//!   invalidity classes of paper §A.2.
//! * [`coarse`] — tier-0 analytic cycle estimator: no program build, no
//!   co-simulation — the cheap fidelity tier the round loop uses to
//!   prescreen candidate pools (`--prescreen-factor`).
//! * [`timing`] — cycle-approximate model: each module has its own timeline
//!   and the dependency-token FIFOs (credit-primed for double buffering /
//!   virtual threads) decide the overlap, exactly the mechanism by which
//!   `nVirtualThread` hides DMA latency on real VTA.
//!
//! [`Simulator`] bundles the three execution modes used by the tuner:
//! `check` (fault + cycle analysis, no data — the profiling fast path),
//! `execute` (full numeric run, used by tests and final validation) and
//! `cycles` (timing only).

pub mod coarse;
pub mod config;
pub mod functional;
pub mod isa;
pub mod layout;
pub mod targets;
pub mod timing;

use config::VtaConfig;
use isa::Program;

/// Why a configuration is *invalid* (paper §A.2: "a register error,
/// requiring a manual reboot, or a test fails because the result differs").
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Fault {
    /// INP/WGT/UOP addressing beyond the physical buffer, or a DRAM range
    /// violation: the device hangs/faults — profiling records a crash.
    RegisterError(String),
    /// Silent data corruption: ACC wraparound or cross-virtual-thread
    /// scratchpad aliasing. The run "succeeds" but the output is wrong.
    Corruption(String),
    /// The dependency-token streams deadlock (malformed program).
    Deadlock(String),
}

impl Fault {
    /// Paper terminology: crashes and wrong outputs are both invalid, but
    /// only crashes abort profiling on the spot.
    pub fn is_crash(&self) -> bool {
        matches!(self, Fault::RegisterError(_) | Fault::Deadlock(_))
    }
}

/// Profiling verdict for one configuration (fast path).
#[derive(Clone, Debug, PartialEq)]
pub enum Verdict {
    /// Executes cleanly; estimated execution cycles.
    Valid { cycles: u64 },
    /// Invalid with the detected fault.
    Invalid { fault: Fault, cycles: u64 },
}

impl Verdict {
    /// Whether the configuration executed cleanly.
    pub fn is_valid(&self) -> bool {
        matches!(self, Verdict::Valid { .. })
    }

    /// Estimated execution cycles (also reported for invalid runs).
    pub fn cycles(&self) -> u64 {
        match self {
            Verdict::Valid { cycles } | Verdict::Invalid { cycles, .. } => {
                *cycles
            }
        }
    }
}

/// Reusable per-worker arena for [`Simulator::check_with`]: the timing
/// co-simulation scratch plus the bounds/hazard scratch, with the
/// nanoseconds each sub-pass took on the last call (the engine feeds
/// those into the `Timing`/`Hazard` telemetry stages). One scratch per
/// worker thread; it never crosses workers (`&mut` API), and reuse is
/// semantically invisible — every buffer is cleared per call, pinned by
/// `tests/sim_scratch.rs`.
#[derive(Debug, Default)]
pub struct SimScratch {
    /// Timing co-simulation arena (streams, token queues, order).
    pub timing: timing::TimingScratch,
    /// Bounds + hazard-sweep arena (windows, access cache, spans).
    pub hazard: functional::HazardScratch,
    /// Wall nanoseconds the timing simulation took on the last check.
    pub timing_ns: u64,
    /// Wall nanoseconds the bounds+hazard passes took on the last check.
    pub hazard_ns: u64,
}

impl SimScratch {
    /// Fresh (cold) scratch; buffers grow on first use and are then
    /// reused forever.
    pub fn new() -> SimScratch {
        SimScratch::default()
    }
}

/// The simulator facade used by the tuner and the experiment harnesses.
#[derive(Clone, Debug)]
pub struct Simulator {
    /// Hardware configuration being simulated.
    pub cfg: VtaConfig,
}

impl Simulator {
    /// Simulator for the given hardware configuration.
    pub fn new(cfg: VtaConfig) -> Self {
        Simulator { cfg }
    }

    /// Fast profiling path: analytic fault detection + cycle model, no data
    /// movement. This is what each tuning-iteration "hardware run" costs us.
    ///
    /// Fault precedence mirrors the board: a register error kills the run
    /// before any output comparison could happen; hazard corruption is only
    /// observable if the program addresses its buffers legally.
    ///
    /// Allocating convenience wrapper over [`Simulator::check_with`];
    /// batch profiling threads one [`SimScratch`] per worker instead.
    pub fn check(&self, prog: &Program) -> Verdict {
        self.check_with(prog, &mut SimScratch::new())
    }

    /// [`Simulator::check`] against a reusable scratch arena —
    /// allocation-free once the arena is warmed to the largest program
    /// seen. Identical verdicts and fault precedence: timing deadlock
    /// first (cycles unknown → 0), then address bounds, then hazards.
    pub fn check_with(
        &self,
        prog: &Program,
        scratch: &mut SimScratch,
    ) -> Verdict {
        let t0 = std::time::Instant::now();
        let timed = timing::simulate_into(&self.cfg, prog, &mut scratch.timing);
        scratch.timing_ns = t0.elapsed().as_nanos() as u64;
        scratch.hazard_ns = 0;
        if let Err(fault) = timed {
            return Verdict::Invalid { fault, cycles: 0 };
        }
        let cycles = scratch.timing.cycles();
        let t1 = std::time::Instant::now();
        let checked = functional::check_program(
            &self.cfg,
            prog,
            scratch.timing.order(),
            &mut scratch.hazard,
        );
        scratch.hazard_ns = t1.elapsed().as_nanos() as u64;
        match checked {
            Err(fault) => Verdict::Invalid { fault, cycles },
            Ok(()) => Verdict::Valid { cycles },
        }
    }

    /// Full numeric execution (slow path). Returns the output DRAM image and
    /// any crash; silent corruption shows up as wrong data, exactly like on
    /// the real board.
    pub fn execute(
        &self,
        prog: &Program,
        dram: &functional::Dram,
    ) -> Result<Vec<i8>, Fault> {
        functional::execute(&self.cfg, prog, dram)
    }

    /// Cycle count alone (no fault analysis).
    pub fn cycles(&self, prog: &Program) -> Result<u64, Fault> {
        timing::simulate(&self.cfg, prog)
    }

    /// Convert cycles to milliseconds at the configured clock.
    pub fn cycles_to_ms(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.cfg.clock_mhz * 1e3)
    }
}
