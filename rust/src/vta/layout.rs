//! DRAM data-layout packing for the VTA GEMM core.
//!
//! The "low-level library" half of the paper's stack ([35]): host tensors are
//! re-laid-out into the accelerator's native units before execution.
//!
//! * input `(H, W, C)` int8 → vectors of `block` int8: index
//!   `(h*W + w)*Cb + cb` where `Cb = C/block`.
//! * weights `(KH, KW, C, KC)` int8 (HWIO, matching the JAX golden model) →
//!   16×16 blocks `[n_lane][k_lane]`, block index
//!   `((nb*KH + kh)*KW + kw)*Cb + cb` — output-channel-block major so a
//!   (kh, kw, ci-chunk) weight slice is a 2-D strided DMA.
//! * output `(OH, OW, KC)` ← accumulator vectors at `(oh*OW + ow)*KCb + nb`.

use super::config::VtaConfig;

/// Pack an `(h, w, c)` int8 image into input vectors. `c % block == 0`.
pub fn pack_input(cfg: &VtaConfig, x: &[i8], h: usize, w: usize, c: usize)
    -> Vec<i8>
{
    let blk = cfg.block();
    assert_eq!(x.len(), h * w * c);
    assert_eq!(c % blk, 0, "channels must be a multiple of block");
    // (h*W + w)*Cb + cb is exactly row-major (h, w, c) — a memcpy.
    x.to_vec()
}

/// Pack `(kh, kw, c, kc)` HWIO int8 weights into GEMM blocks.
pub fn pack_weights(
    cfg: &VtaConfig,
    wt: &[i8],
    kh: usize,
    kw: usize,
    c: usize,
    kc: usize,
) -> Vec<i8> {
    let blk = cfg.block();
    assert_eq!(wt.len(), kh * kw * c * kc);
    assert_eq!(c % blk, 0);
    assert_eq!(kc % blk, 0);
    let (cb_n, nb_n) = (c / blk, kc / blk);
    let bytes = cfg.wgt_block_bytes();
    let mut out = vec![0i8; nb_n * kh * kw * cb_n * bytes];
    for nb in 0..nb_n {
        for ih in 0..kh {
            for iw in 0..kw {
                for cb in 0..cb_n {
                    let blk_idx = ((nb * kh + ih) * kw + iw) * cb_n + cb;
                    let base = blk_idx * bytes;
                    for n_lane in 0..blk {
                        for k_lane in 0..blk {
                            // HWIO: ((ih*KW + iw)*C + ci)*KC + co
                            let ci = cb * blk + k_lane;
                            let co = nb * blk + n_lane;
                            let src = ((ih * kw + iw) * c + ci) * kc + co;
                            out[base + n_lane * blk + k_lane] = wt[src];
                        }
                    }
                }
            }
        }
    }
    out
}

/// Number of weight blocks `pack_weights` produces.
pub fn weight_blocks(
    cfg: &VtaConfig,
    kh: usize,
    kw: usize,
    c: usize,
    kc: usize,
) -> usize {
    let blk = cfg.block();
    (kc / blk) * kh * kw * (c / blk)
}

/// Output DRAM is stored as int8 lanes of accumulator vectors laid out
/// `(oh*OW + ow)*KCb + nb`; as with the input this is row-major
/// `(oh, ow, kc)` — identity. Provided for symmetry / documentation.
pub fn unpack_output(
    cfg: &VtaConfig,
    out_vecs: &[i8],
    oh: usize,
    ow: usize,
    kc: usize,
) -> Vec<i8> {
    let blk = cfg.block();
    assert_eq!(kc % blk, 0);
    assert_eq!(out_vecs.len(), oh * ow * kc);
    out_vecs.to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn input_pack_is_identity_layout() {
        let cfg = VtaConfig::zcu102();
        let mut r = Rng::new(1);
        let x: Vec<i8> = (0..2 * 3 * 16).map(|_| r.i8()).collect();
        assert_eq!(pack_input(&cfg, &x, 2, 3, 16), x);
    }

    #[test]
    fn weight_block_lanes() {
        let cfg = VtaConfig::zcu102();
        let (kh, kw, c, kc) = (3, 3, 32, 16);
        let mut r = Rng::new(2);
        let wt: Vec<i8> = (0..kh * kw * c * kc).map(|_| r.i8()).collect();
        let packed = pack_weights(&cfg, &wt, kh, kw, c, kc);
        assert_eq!(packed.len(), weight_blocks(&cfg, kh, kw, c, kc) * 256);
        // spot check: block (nb=0, ih=1, iw=2, cb=1), n_lane=3, k_lane=5
        let blk = 16;
        let (nb, ih, iw, cb, n_lane, k_lane) = (0, 1, 2, 1, 3, 5);
        let cb_n = c / blk;
        let blk_idx = ((nb * kh + ih) * kw + iw) * cb_n + cb;
        let got = packed[blk_idx * 256 + n_lane * blk + k_lane];
        let src = ((ih * kw + iw) * c + (cb * blk + k_lane)) * kc
            + (nb * blk + n_lane);
        assert_eq!(got, wt[src]);
    }

    #[test]
    #[should_panic]
    fn rejects_non_multiple_channels() {
        let cfg = VtaConfig::zcu102();
        let x = vec![0i8; 2 * 2 * 10];
        pack_input(&cfg, &x, 2, 2, 10);
    }
}
