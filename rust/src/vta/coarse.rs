//! Tier-0 analytic cycle estimator — the coarse prescreen fidelity tier.
//!
//! Full profiling builds a program and co-simulates three module timelines
//! ([`crate::vta::timing`]); that cycle-accuracy is what a tuning round
//! pays for every selected candidate. This module estimates the same
//! quantity *without lowering anything*: it resolves the tile geometry
//! ([`crate::compiler::passes::analyze`]), applies the weak static
//! capacity check ([`crate::compiler::validity::static_check`]), and sums
//! per-module cycle contributions from the [`VtaConfig`] timing
//! coefficients — DMA bytes over stream width, GEMM block-operations at
//! one per cycle, uop-table fetch, requantization ALU — assuming perfect
//! pipeline overlap (the per-tile bottleneck module dominates).
//!
//! The estimate is *not* cycle-accurate: it ignores token-FIFO stalls,
//! per-thread slice pressure, and boundary-tile raggedness. It exists to
//! **rank** a candidate pool so the round loop can spend full
//! `vta::timing` profiling on the survivors only (`--prescreen-factor`),
//! and its contract is correspondingly weak: monotone-consistent with the
//! static check (Hopeless here ⇒ Hopeless there, so a statically doomed
//! config can never out-rank a plausible one) and rank-correlated with
//! the full simulator on plausible configs. Estimates that do enter the
//! tuning database are tagged [`crate::tuner::database::Fidelity::Coarse`]
//! so no model or transfer consumer mistakes them for measurements.

use crate::compiler::passes::{analyze, TileAnalysis};
use crate::compiler::schedule::Schedule;
use crate::compiler::validity::{static_check, StaticCheck};
use crate::vta::config::VtaConfig;
use crate::workloads::ConvLayer;

/// Tier-0 verdict for one (layer, schedule) pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoarseEstimate {
    /// The static capacity check rejected the footprint: the config can
    /// never execute, so it must never survive a prescreen ranking.
    Hopeless,
    /// Analytic cycle estimate (rank signal, not a measurement).
    Cycles(u64),
}

impl CoarseEstimate {
    /// Whether the static check rejected the configuration.
    pub fn is_hopeless(&self) -> bool {
        matches!(self, CoarseEstimate::Hopeless)
    }

    /// The estimated cycles, if the config is statically plausible.
    pub fn cycles(&self) -> Option<u64> {
        match self {
            CoarseEstimate::Hopeless => None,
            CoarseEstimate::Cycles(c) => Some(*c),
        }
    }

    /// Ranking key: plausible estimates order by cycles, Hopeless sorts
    /// after every finite estimate.
    pub fn rank_key(&self) -> u64 {
        match self {
            CoarseEstimate::Hopeless => u64::MAX,
            CoarseEstimate::Cycles(c) => *c,
        }
    }
}

/// Estimate execution cycles for one (layer, schedule) pair on `cfg`
/// without building a program.
///
/// Cost: one [`analyze`] pass plus O(1) arithmetic — no instruction
/// stream, no three-timeline co-simulation. See the module docs for the
/// accuracy contract.
pub fn estimate(
    cfg: &VtaConfig,
    layer: &ConvLayer,
    sched: &Schedule,
) -> CoarseEstimate {
    let a = analyze(cfg, layer, sched);
    estimate_analyzed(cfg, layer, &a)
}

/// [`estimate`] over an already-resolved [`TileAnalysis`] (callers that
/// have one avoid the duplicate `analyze` pass).
pub fn estimate_analyzed(
    cfg: &VtaConfig,
    layer: &ConvLayer,
    a: &TileAnalysis,
) -> CoarseEstimate {
    if let StaticCheck::Hopeless(_) = static_check(cfg, a) {
        return CoarseEstimate::Hopeless;
    }

    let bpc = cfg.dma_bytes_per_cycle.max(1);
    let dma = |bytes: u64, rows: u64| {
        cfg.dma_latency + bytes.div_ceil(bpc) + rows * cfg.dma_row_overhead
    };

    // LOAD timeline: per channel chunk, one input-halo DMA and one
    // weight-chunk DMA (mirrors `instr_cycles` for `Opcode::Load`).
    let inp_bytes = (a.inp_tile * cfg.inp_vec_bytes()) as u64;
    let wgt_bytes = (a.wgt_chunk * cfg.wgt_block_bytes()) as u64;
    let load = a.n_ci as u64
        * (dma(inp_bytes, a.in_tile_h as u64)
            + dma(wgt_bytes, a.nbc as u64));

    // COMPUTE timeline: uop-table fetch, accumulator memset, the GEMM
    // block-operations (one 16×16×16 MAC block per cycle, plus the issue
    // overhead per GEMM instruction), and the requantizing ALU pass.
    let uop_fetch = dma((a.uop_count * cfg.uop_bytes()) as u64, 0);
    let memset = 8 + a.acc_tile as u64 * cfg.memset_cycles_per_vec;
    let block_ops =
        (a.th * a.tw * a.nbc * a.cbc * a.n_pos * a.n_ci) as u64;
    let gemm_issue =
        (a.th * a.tw * a.n_chunks * a.n_ci) as u64 * cfg.gemm_overhead;
    let alu =
        cfg.alu_overhead + a.acc_tile as u64 * cfg.alu_cycles_per_vec;
    let compute = uop_fetch + memset + block_ops + gemm_issue + alu;

    // STORE timeline: the requantized int8 output tile back to DRAM.
    let store = dma((a.acc_tile * cfg.block()) as u64, a.th as u64);

    // Steady state: with double buffering or virtual threads the three
    // modules overlap and the slowest one paces the pipeline; a
    // single-buffered single-thread schedule serializes them. One
    // DMA-latency of pipeline fill plus the FINISH handshake on top.
    let overlapped = a.slots >= 2 || a.nvt >= 2;
    let per_tile = if overlapped {
        load.max(compute).max(store)
    } else {
        load + compute + store
    };
    let _ = layer; // geometry is fully captured by the analysis
    CoarseEstimate::Cycles(
        a.n_tiles() as u64 * per_tile + cfg.dma_latency + cfg.finish_cycles,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::resnet18;

    fn sched(th: usize, tw: usize, oc: usize, ic: usize, vt: usize)
        -> Schedule
    {
        Schedule { tile_h: th, tile_w: tw, tile_oc: oc, tile_ic: ic,
                   n_vthreads: vt, ..Default::default() }
    }

    #[test]
    fn hopeless_mirrors_static_check() {
        let cfg = VtaConfig::zcu102();
        let l = resnet18::layer("conv1").unwrap();
        // acc 56·56·4 = 12544 > 4096 → statically hopeless
        let s = sched(56, 56, 64, 64, 1);
        assert_eq!(estimate(&cfg, &l, &s), CoarseEstimate::Hopeless);
        assert!(!static_check(&cfg, &analyze(&cfg, &l, &s)).is_plausible());
        // and a comfortably plausible one gets a finite estimate
        let ok = estimate(&cfg, &l, &sched(8, 8, 32, 32, 1));
        assert!(ok.cycles().is_some());
    }

    #[test]
    fn hopeless_ranks_after_every_estimate() {
        assert!(CoarseEstimate::Hopeless.rank_key()
                > CoarseEstimate::Cycles(u64::MAX - 1).rank_key());
    }

    #[test]
    fn per_tile_overheads_penalize_tiny_tiles() {
        // 1×1 tiles pay the DMA setup latency per output pixel; a tile
        // an order of magnitude larger amortizes it. The estimator must
        // preserve that first-order ordering.
        let cfg = VtaConfig::zcu102();
        let l = resnet18::layer("conv1").unwrap();
        let tiny = estimate(&cfg, &l, &sched(1, 1, 16, 16, 1));
        let big = estimate(&cfg, &l, &sched(14, 14, 32, 32, 1));
        assert!(tiny.cycles().unwrap() > 4 * big.cycles().unwrap(),
                "tiny {tiny:?} vs big {big:?}");
    }

    #[test]
    fn serial_schedules_estimate_slower_than_overlapped() {
        let cfg = VtaConfig::zcu102();
        let l = resnet18::layer("conv1").unwrap();
        let base = sched(8, 8, 32, 32, 1);
        let serial = Schedule { n_load_slots: 1, ..base };
        let e_overlap = estimate(&cfg, &l, &base).cycles().unwrap();
        let e_serial = estimate(&cfg, &l, &serial).cycles().unwrap();
        assert!(e_serial > e_overlap);
    }
}
