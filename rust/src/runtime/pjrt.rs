//! PJRT CPU client wrapper: HLO-text load, compile cache, execution.
//!
//! Interchange is HLO **text**, not serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;
use crate::workloads::ConvLayer;

/// Per-layer artifact metadata from `manifest.json`.
#[derive(Clone, Debug)]
pub struct LayerArtifact {
    /// HLO-text artifact filename (relative to the artifact dir).
    pub artifact: String,
    /// Requantization shift the golden model bakes in.
    pub shift: u32,
    /// Layer shape the artifact computes.
    pub layer: ConvLayer,
}

/// The runtime: PJRT client + compiled-executable cache + manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Json,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open `artifacts_dir` (must contain `manifest.json`).
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {mpath:?} — run `make artifacts` first"))?;
        let manifest = Json::parse(&text)
            .map_err(|e| anyhow!("parsing {mpath:?}: {e}"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime { client, dir, manifest, cache: HashMap::new() })
    }

    /// Default artifacts location (repo-root `artifacts/`).
    pub fn open_default() -> Result<Self> {
        Self::new("artifacts")
    }

    /// Requantization shift the artifacts were lowered with.
    pub fn shift(&self) -> u32 {
        self.manifest
            .at(&["shift"])
            .and_then(Json::as_i64)
            .unwrap_or(8) as u32
    }

    /// Layer names present in the manifest.
    pub fn layer_names(&self) -> Vec<String> {
        self.manifest
            .at(&["layers"])
            .and_then(Json::as_obj)
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default()
    }

    /// Cross-check a rust-side layer against the manifest entry.
    pub fn check_layer(&self, layer: &ConvLayer) -> Result<()> {
        let entry = self
            .manifest
            .at(&["layers", layer.name])
            .ok_or_else(|| anyhow!("{} not in manifest", layer.name))?;
        let get = |k: &str| entry.get(k).and_then(Json::as_usize);
        let fields = [
            ("h", layer.h), ("w", layer.w), ("c", layer.c),
            ("kc", layer.kc), ("kh", layer.kh), ("kw", layer.kw),
            ("oh", layer.oh), ("ow", layer.ow),
            ("pad", layer.pad), ("stride", layer.stride),
        ];
        for (k, v) in fields {
            if get(k) != Some(v) {
                bail!(
                    "manifest/{}: field {k} mismatch (manifest {:?}, rust {v})",
                    layer.name,
                    get(k)
                );
            }
        }
        Ok(())
    }

    fn executable(
        &mut self,
        layer: &ConvLayer,
    ) -> Result<&xla::PjRtLoadedExecutable> {
        let artifact = self
            .manifest
            .at(&["layers", layer.name, "artifact"])
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("{}: no artifact in manifest", layer.name))?
            .to_string();
        if !self.cache.contains_key(&artifact) {
            let path = self.dir.join(&artifact);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("loading {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {artifact}: {e:?}"))?;
            self.cache.insert(artifact.clone(), exe);
        }
        Ok(&self.cache[&artifact])
    }

    /// Execute the golden conv for `layer`: `(x: i32[H,W,C], w: i32[KH,KW,
    /// C,KC]) → i32[OH,OW,KC]`. Values must be int8-range (the graph casts).
    pub fn execute_conv(
        &mut self,
        layer: &ConvLayer,
        x_i32: &[i32],
        w_i32: &[i32],
    ) -> Result<Vec<i32>> {
        assert_eq!(x_i32.len(), layer.input_len());
        assert_eq!(w_i32.len(), layer.weight_len());
        let x = xla::Literal::vec1(x_i32)
            .reshape(&[layer.h as i64, layer.w as i64, layer.c as i64])
            .map_err(|e| anyhow!("reshape x: {e:?}"))?;
        let w = xla::Literal::vec1(w_i32)
            .reshape(&[
                layer.kh as i64,
                layer.kw as i64,
                layer.c as i64,
                layer.kc as i64,
            ])
            .map_err(|e| anyhow!("reshape w: {e:?}"))?;
        let exe = self.executable(layer)?;
        let result = exe
            .execute::<xla::Literal>(&[x, w])
            .map_err(|e| anyhow!("execute {}: {e:?}", layer.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // lowered with return_tuple=True → 1-tuple
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        out.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
