//! Golden-output helper: deterministic tensors → golden conv via PJRT.
//!
//! The profiling step's "expected result" (paper §2: "Validity is assessed
//! by checking for crashes and verifying output correctness").

use anyhow::Result;

use super::pjrt::Runtime;
use crate::workloads::{synth, ConvLayer};

/// Golden int8 output `(OH, OW, KC)` for `layer` under seed-derived data.
pub fn golden_output(
    rt: &mut Runtime,
    layer: &ConvLayer,
    seed: u64,
) -> Result<Vec<i8>> {
    let x = synth::input_data(layer, seed);
    let w = synth::weight_data(layer, seed);
    let xi: Vec<i32> = x.iter().map(|&v| v as i32).collect();
    let wi: Vec<i32> = w.iter().map(|&v| v as i32).collect();
    let out = rt.execute_conv(layer, &xi, &wi)?;
    Ok(out.iter().map(|&v| v as i8).collect())
}

/// Pure-rust reference conv with identical VTA semantics (int8 × int8 →
/// int32 accumulate → arithmetic shift → clip). Used by tests to triangulate
/// simulator ↔ golden-model agreement without PJRT, and by the quickstart
/// when artifacts are absent.
pub fn reference_conv(
    layer: &ConvLayer,
    x: &[i8],
    w: &[i8],
    shift: u32,
) -> Vec<i8> {
    assert_eq!(x.len(), layer.input_len());
    assert_eq!(w.len(), layer.weight_len());
    let mut out = vec![0i8; layer.output_len()];
    for oh in 0..layer.oh {
        for ow_ in 0..layer.ow {
            for oc in 0..layer.kc {
                let mut acc = 0i32;
                for kh in 0..layer.kh {
                    for kw in 0..layer.kw {
                        let ih = oh as isize * layer.stride as isize
                            + kh as isize
                            - layer.pad as isize;
                        let iw = ow_ as isize * layer.stride as isize
                            + kw as isize
                            - layer.pad as isize;
                        if ih < 0
                            || ih >= layer.h as isize
                            || iw < 0
                            || iw >= layer.w as isize
                        {
                            continue;
                        }
                        let (ih, iw) = (ih as usize, iw as usize);
                        for c in 0..layer.c {
                            let xv = x
                                [(ih * layer.w + iw) * layer.c + c]
                                as i32;
                            let wv = w[((kh * layer.kw + kw) * layer.c
                                + c)
                                * layer.kc
                                + oc] as i32;
                            acc += xv * wv;
                        }
                    }
                }
                out[(oh * layer.ow + ow_) * layer.kc + oc] =
                    (acc >> shift).clamp(-128, 127) as i8;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::resnet18;

    #[test]
    fn reference_conv_identity_1x1() {
        // 1×1 kernel, identity-ish weights: w[c][oc] = 16·δ(c==oc·…)
        let layer = ConvLayer {
            name: "t", h: 2, w: 2, c: 16, kc: 16, kh: 1, kw: 1,
            oh: 2, ow: 2, pad: 0, stride: 1,
        };
        let x: Vec<i8> = (0..layer.input_len())
            .map(|i| (i % 100) as i8)
            .collect();
        // w = 2^shift · I → output == input
        let shift = 4u32;
        let mut w = vec![0i8; layer.weight_len()];
        for c in 0..16 {
            w[c * 16 + c] = 1 << shift;
        }
        let out = reference_conv(&layer, &x, &w, shift);
        assert_eq!(out, x);
    }

    #[test]
    fn reference_conv_padding_zeros() {
        let layer = ConvLayer {
            name: "t", h: 4, w: 4, c: 16, kc: 16, kh: 3, kw: 3,
            oh: 4, ow: 4, pad: 1, stride: 1,
        };
        let x = vec![1i8; layer.input_len()];
        let w = vec![1i8; layer.weight_len()];
        let out = reference_conv(&layer, &x, &w, 0);
        // corner output: only 4 of 9 taps in-bounds → 4*16 = 64
        assert_eq!(out[0], 64);
        // centre output: 9*16 = 144 → clipped to 127
        assert_eq!(out[(1 * 4 + 1) * 16], 127);
    }

    #[test]
    fn shift_floor_negative() {
        let layer = ConvLayer {
            name: "t", h: 1, w: 1, c: 16, kc: 16, kh: 1, kw: 1,
            oh: 1, ow: 1, pad: 0, stride: 1,
        };
        let mut x = vec![0i8; 16];
        x[0] = -1;
        let mut w = vec![0i8; 16 * 16];
        w[0] = 1; // out = -1 >> 8 = -1 (arithmetic floor)
        let out = reference_conv(&layer, &x, &w, 8);
        assert_eq!(out[0], -1);
    }

    #[test]
    fn works_on_paper_layers() {
        // smoke: shapes line up for every Table 2a layer (tiny data check
        // done via conv5 which is smallest)
        let l = resnet18::layer("conv5").unwrap();
        let x = synth::input_data(&l, 1);
        let w = synth::weight_data(&l, 1);
        let out = reference_conv(&l, &x, &w, 8);
        assert_eq!(out.len(), l.output_len());
    }
}
