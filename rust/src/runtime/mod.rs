//! PJRT runtime — executes the AOT-compiled JAX/Pallas golden models.
//!
//! `make artifacts` lowers each ResNet18 conv layer to HLO *text*
//! (`artifacts/*.hlo.txt` + `manifest.json`); this module loads the text,
//! compiles it once on the PJRT CPU client and executes it with concrete
//! tensors. Python never runs on the tuning path — the rust binary is
//! self-contained once artifacts exist.
//!
//! During profiling the golden output is the "expected result" of the
//! paper's validity check: a simulated run is *valid* iff it neither
//! crashed nor differs bit-wise from the golden model.

pub mod golden;
pub mod pjrt;

pub use pjrt::Runtime;
