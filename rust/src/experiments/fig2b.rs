//! Fig. 2(b): invalidity ratio of proposed configurations (left) and
//! normalized histogram of execution times for the valid configurations
//! (right), ML²Tuner vs TVM vs random, Conv1 and Conv2.

use super::{data, ExpConfig};
use crate::util::stats::normalized_histogram;
use crate::util::table::{f, Table};

/// Render the Fig. 2(b) invalidity/histogram reproduction.
pub fn run(cfg: &ExpConfig) -> String {
    let (repeats, ml2_t, tvm_t) =
        if cfg.quick { (cfg.repeats, 120, 120) } else { (cfg.repeats, 300, 300) };
    let clock = cfg.hw.clock_mhz;
    let mut out = String::from(
        "== Fig 2(b): invalidity ratio + execution-time histogram ==\n\
         (paper Conv1: random 0.926, TVM 0.492, ML2Tuner 0.176)\n\n",
    );
    for layer in ["conv1", "conv2"] {
        let runs =
            data::compare_on_layer(&cfg.hw, layer, repeats, ml2_t,
                                   tvm_t, cfg.seed);
        let mut t = Table::new(&["tuner", "invalidity ratio"]);
        t.row(&["random".into(), f(data::mean_invalidity(&runs.random), 3)]);
        t.row(&["tvm".into(), f(data::mean_invalidity(&runs.tvm), 3)]);
        t.row(&["ml2tuner".into(), f(data::mean_invalidity(&runs.ml2), 3)]);
        out.push_str(&format!("--- {layer} ---\n"));
        out.push_str(&t.render());

        // normalized histogram over valid execution times (both tuners
        // binned on the shared range, as in the paper's overlay)
        let ms = |traces: &[crate::tuner::report::TuningTrace]| {
            traces
                .iter()
                .flat_map(|t| t.valid_cycles())
                .map(|c| c / (clock * 1e3))
                .collect::<Vec<f64>>()
        };
        let mut all = ms(&runs.ml2);
        all.extend(ms(&runs.tvm));
        if !all.is_empty() {
            let bins = 10;
            let hist = |xs: &[f64]| {
                // bin on the combined range for comparability
                let lo = crate::util::stats::min(&all);
                let hi = crate::util::stats::max(&all);
                let w = ((hi - lo) / bins as f64).max(1e-12);
                let mut counts = vec![0usize; bins];
                for &x in xs {
                    counts[(((x - lo) / w) as usize).min(bins - 1)] += 1;
                }
                counts
                    .iter()
                    .map(|&c| c as f64 / xs.len().max(1) as f64)
                    .collect::<Vec<f64>>()
            };
            let hm = hist(&ms(&runs.ml2));
            let ht = hist(&ms(&runs.tvm));
            let mut ht_t = Table::new(&["bin", "ml2tuner", "tvm"]);
            for b in 0..bins {
                ht_t.row(&[b.to_string(), f(hm[b], 3), f(ht[b], 3)]);
            }
            out.push_str("\nnormalized exec-time histogram (valid \
                          configs, shared bins low→high):\n");
            out.push_str(&ht_t.render());
            let mass_low_ml2: f64 = hm[..bins / 2].iter().sum();
            let mass_low_tvm: f64 = ht[..bins / 2].iter().sum();
            out.push_str(&format!(
                "low-half mass: ml2tuner {:.3} vs tvm {:.3} (paper: \
                 ML2Tuner histogram is left-shifted)\n\n",
                mass_low_ml2, mass_low_tvm
            ));
        }
        let _ = normalized_histogram(&all, 10); // (shared util exercised)
    }
    out
}
