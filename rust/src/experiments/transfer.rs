//! Cross-workload transfer warm-start: cold vs warm sample-efficiency.
//!
//! Beyond-paper experiment (the registry + `TransferDb` subsystem; cf.
//! MetaTune and HW-Aware Initialization in PAPERS.md). Protocol:
//!
//! 1. tune three *sibling* layers of the MobileNet-style network with
//!    ML²Tuner and bank their tuning logs in a [`TransferDb`];
//! 2. tune the held-out target layer (`pw5`) cold and warm-started from
//!    the bank, with paired seeds;
//! 3. report, per repeat, how many profiled samples the warm run needs
//!    to reach the cold run's final best cycles, and the averaged
//!    best-so-far curves.
//!
//! The warm tuner is model-guided from its first batch (the transferred
//! records satisfy the `min_train` gate), so the expected effect is the
//! MetaTune one: same final quality, reached with a fraction of the
//! profiled samples.
//!
//! With `--meta` a third arm is added: warm start *plus* a
//! corpus-trained [`MetaArtifact`] built over the same source logs
//! (what `train-meta` would produce offline). Its per-round fits adapt
//! the meta ensembles instead of starting cold, so the comparison
//! isolates what the meta base buys on top of transferred records.

use super::ExpConfig;
use crate::compiler::schedule::SpaceKind;
use crate::engine::Engine;
use crate::tuner::database::{Database, TransferDb};
use crate::tuner::meta::{MetaArtifact, META_BOOST_ROUNDS};
use crate::tuner::ml2tuner::Ml2Tuner;
use crate::tuner::report::{average_curves, TuningTrace};
use crate::tuner::{Tuner, TunerConfig, TuningEnv};
use crate::util::stats::mean;
use crate::util::table::{f, Table};
use crate::workloads;

const SOURCE_LAYERS: [&str; 3] = ["pw3", "pw4", "pw6"];
const TARGET_LAYER: &str = "pw5";

/// Render the cold-vs-warm transfer warm-start study.
pub fn run(cfg: &ExpConfig) -> String {
    let (src_trials, tgt_trials, cap) = if cfg.quick {
        (60, 60, 200)
    } else {
        (200, 200, 400)
    };
    let net = workloads::network("mobilenet").unwrap();
    let target = net.layer(TARGET_LAYER).unwrap();
    let engine = Engine::default();

    // -- 1. bank sibling-layer tuning logs --------------------------------
    let mut store = TransferDb::new();
    for name in SOURCE_LAYERS {
        let layer = net.layer(name).unwrap();
        let env = TuningEnv::new(cfg.hw.clone(), layer);
        let t_cfg = TunerConfig {
            seed: cfg.seed ^ 0x5eed_0001,
            max_trials: src_trials,
            ..Default::default()
        };
        let trace = Ml2Tuner::new(t_cfg).tune_with(&env, &engine);
        let mut db =
            Database::for_layer_on(&layer, SpaceKind::Paper, &cfg.hw);
        for r in &trace.trials {
            db.push(r.clone());
        }
        store.add(db);
    }
    let warm = store
        .warm_start_for(&target, SpaceKind::Paper, &cfg.hw, cap)
        .expect("sibling layers must transfer");
    // the --meta arm's artifact: offline corpus training over the same
    // source logs (exactly what `train-meta` on the banked dirs yields)
    let meta = cfg.meta.then(|| {
        let dbs: Vec<&Database> =
            store.sources.iter().map(|d| d.as_ref()).collect();
        let rounds =
            if cfg.quick { 120 } else { META_BOOST_ROUNDS };
        MetaArtifact::build(SpaceKind::Paper, &dbs, rounds)
    });

    // -- 2. cold vs warm on the held-out layer, paired seeds --------------
    let env = TuningEnv::new(cfg.hw.clone(), target);
    let mut cold_runs: Vec<TuningTrace> = Vec::new();
    let mut warm_runs: Vec<TuningTrace> = Vec::new();
    let mut meta_runs: Vec<TuningTrace> = Vec::new();
    for r in 0..cfg.repeats {
        let s = cfg.seed ^ (r as u64).wrapping_mul(0x9e37_79b9);
        let t_cfg = TunerConfig {
            seed: s,
            max_trials: tgt_trials,
            ..Default::default()
        };
        cold_runs
            .push(Ml2Tuner::new(t_cfg.clone()).tune_with(&env, &engine));
        warm_runs.push(
            Ml2Tuner::new(t_cfg.clone())
                .with_warm_start(warm.clone())
                .tune_with(&env, &engine),
        );
        if let Some(art) = &meta {
            meta_runs.push(
                Ml2Tuner::new(t_cfg)
                    .with_warm_start(warm.clone())
                    .with_meta(art.clone())
                    .tune_with(&env, &engine),
            );
        }
    }

    // -- 3. report --------------------------------------------------------
    let mut out = format!(
        "== transfer warm-start: cold vs warm{} on \
         mobilenet/{TARGET_LAYER} ==\n(sources: {}; {} transferred \
         records; {} repeats x {} trials)\n\n",
        if meta.is_some() { " vs warm+meta" } else { "" },
        SOURCE_LAYERS.join(", "),
        warm.len(),
        cfg.repeats,
        tgt_trials
    );
    let curve_avg = |runs: &[TuningTrace]| {
        average_curves(
            &runs.iter().map(|t| t.best_curve()).collect::<Vec<_>>(),
        )
    };
    let cold_avg = curve_avg(&cold_runs);
    let warm_avg = curve_avg(&warm_runs);
    let meta_avg = meta.as_ref().map(|_| curve_avg(&meta_runs));
    let mut headers = vec![
        "configs tested",
        "cold best (cycles)",
        "warm best (cycles)",
    ];
    if meta.is_some() {
        headers.push("warm+meta best (cycles)");
    }
    let mut t = Table::new(&headers);
    let cell = |curve: &[f64], i: usize| {
        let v = curve.get(i).copied().unwrap_or(f64::INFINITY);
        if v.is_finite() { f(v, 0) } else { "-".to_string() }
    };
    let step = 10;
    let mut i = step - 1;
    while i < cold_avg.len().max(warm_avg.len()) {
        let mut row = vec![
            (i + 1).to_string(),
            cell(&cold_avg, i),
            cell(&warm_avg, i),
        ];
        if let Some(m) = &meta_avg {
            row.push(cell(m, i));
        }
        t.row(&row);
        i += step;
    }
    out.push_str(&t.render());

    // paired sample-efficiency: samples an arm needs to match the cold
    // run's final best, over the samples the cold run took to get there
    let pair = |runs: &[TuningTrace]| {
        let mut fracs = Vec::new();
        let mut wins = 0usize;
        let mut reached = 0usize;
        for (c, w) in cold_runs.iter().zip(runs) {
            let Some(cold_best) = c.best_cycles() else { continue };
            let cold_at = c.trials_to_reach(cold_best as f64).unwrap();
            match w.trials_to_reach(cold_best as f64) {
                Some(at) => {
                    reached += 1;
                    if at < cold_at {
                        wins += 1;
                    }
                    fracs.push(at as f64 / cold_at as f64);
                }
                None => fracs.push(f64::NAN),
            }
        }
        (reached, wins, fracs)
    };
    let mut arm_line = |label: &str, runs: &[TuningTrace]| {
        let (reached, wins, fracs) = pair(runs);
        let finite: Vec<f64> =
            fracs.iter().copied().filter(|v| v.is_finite()).collect();
        if finite.is_empty() {
            out.push_str(&format!(
                "\n{label} runs never reached the cold best within \
                 budget\n"
            ));
        } else {
            out.push_str(&format!(
                "\n{label} reaches the cold run's best cycles in {}/{} \
                 repeats, using {:.1}% of the cold run's samples on \
                 average ({label} strictly fewer in {}/{})\n",
                reached,
                cold_runs.len(),
                100.0 * mean(&finite),
                wins,
                cold_runs.len(),
            ));
        }
    };
    arm_line("warm", &warm_runs);
    if meta.is_some() {
        arm_line("warm+meta", &meta_runs);
    }
    let final_mean = |runs: &[TuningTrace]| {
        mean(
            &runs
                .iter()
                .filter_map(|t| t.best_cycles().map(|c| c as f64))
                .collect::<Vec<_>>(),
        )
    };
    out.push_str(&format!(
        "final best (mean): cold {} vs warm {} cycles\n",
        f(final_mean(&cold_runs), 0),
        f(final_mean(&warm_runs), 0)
    ));
    if meta.is_some() {
        out.push_str(&format!(
            "final best (mean), warm+meta: {} cycles\n",
            f(final_mean(&meta_runs), 0)
        ));
    }
    out
}
