//! Cross-workload transfer warm-start: cold vs warm sample-efficiency.
//!
//! Beyond-paper experiment (the registry + `TransferDb` subsystem; cf.
//! MetaTune and HW-Aware Initialization in PAPERS.md). Protocol:
//!
//! 1. tune three *sibling* layers of the MobileNet-style network with
//!    ML²Tuner and bank their tuning logs in a [`TransferDb`];
//! 2. tune the held-out target layer (`pw5`) cold and warm-started from
//!    the bank, with paired seeds;
//! 3. report, per repeat, how many profiled samples the warm run needs
//!    to reach the cold run's final best cycles, and the averaged
//!    best-so-far curves.
//!
//! The warm tuner is model-guided from its first batch (the transferred
//! records satisfy the `min_train` gate), so the expected effect is the
//! MetaTune one: same final quality, reached with a fraction of the
//! profiled samples.

use super::ExpConfig;
use crate::engine::Engine;
use crate::tuner::database::{Database, TransferDb};
use crate::tuner::ml2tuner::Ml2Tuner;
use crate::tuner::report::{average_curves, TuningTrace};
use crate::tuner::{Tuner, TunerConfig, TuningEnv};
use crate::util::stats::mean;
use crate::util::table::{f, Table};
use crate::workloads;

const SOURCE_LAYERS: [&str; 3] = ["pw3", "pw4", "pw6"];
const TARGET_LAYER: &str = "pw5";

/// Render the cold-vs-warm transfer warm-start study.
pub fn run(cfg: &ExpConfig) -> String {
    let (src_trials, tgt_trials, cap) = if cfg.quick {
        (60, 60, 200)
    } else {
        (200, 200, 400)
    };
    let net = workloads::network("mobilenet").unwrap();
    let target = net.layer(TARGET_LAYER).unwrap();
    let engine = Engine::default();

    // -- 1. bank sibling-layer tuning logs --------------------------------
    let mut store = TransferDb::new();
    for name in SOURCE_LAYERS {
        let layer = net.layer(name).unwrap();
        let env = TuningEnv::new(cfg.hw.clone(), layer);
        let t_cfg = TunerConfig {
            seed: cfg.seed ^ 0x5eed_0001,
            max_trials: src_trials,
            ..Default::default()
        };
        let trace = Ml2Tuner::new(t_cfg).tune_with(&env, &engine);
        let mut db = Database::for_layer_on(
            &layer, crate::compiler::schedule::SpaceKind::Paper, &cfg.hw,
        );
        for r in &trace.trials {
            db.push(r.clone());
        }
        store.add(db);
    }
    let warm = store
        .warm_start_for(&target, crate::compiler::schedule::SpaceKind::Paper,
                        &cfg.hw, cap)
        .expect("sibling layers must transfer");

    // -- 2. cold vs warm on the held-out layer, paired seeds --------------
    let env = TuningEnv::new(cfg.hw.clone(), target);
    let mut cold_runs: Vec<TuningTrace> = Vec::new();
    let mut warm_runs: Vec<TuningTrace> = Vec::new();
    for r in 0..cfg.repeats {
        let s = cfg.seed ^ (r as u64).wrapping_mul(0x9e37_79b9);
        let t_cfg = TunerConfig {
            seed: s,
            max_trials: tgt_trials,
            ..Default::default()
        };
        cold_runs
            .push(Ml2Tuner::new(t_cfg.clone()).tune_with(&env, &engine));
        warm_runs.push(
            Ml2Tuner::new(t_cfg)
                .with_warm_start(warm.clone())
                .tune_with(&env, &engine),
        );
    }

    // -- 3. report --------------------------------------------------------
    let mut out = format!(
        "== transfer warm-start: cold vs warm on mobilenet/{TARGET_LAYER} \
         ==\n(sources: {}; {} transferred records; {} repeats x {} \
         trials)\n\n",
        SOURCE_LAYERS.join(", "),
        warm.len(),
        cfg.repeats,
        tgt_trials
    );
    let cold_avg = average_curves(
        &cold_runs.iter().map(|t| t.best_curve()).collect::<Vec<_>>(),
    );
    let warm_avg = average_curves(
        &warm_runs.iter().map(|t| t.best_curve()).collect::<Vec<_>>(),
    );
    let mut t = Table::new(&[
        "configs tested",
        "cold best (cycles)",
        "warm best (cycles)",
    ]);
    let cell = |curve: &[f64], i: usize| {
        let v = curve.get(i).copied().unwrap_or(f64::INFINITY);
        if v.is_finite() { f(v, 0) } else { "-".to_string() }
    };
    let step = 10;
    let mut i = step - 1;
    while i < cold_avg.len().max(warm_avg.len()) {
        t.row(&[
            (i + 1).to_string(),
            cell(&cold_avg, i),
            cell(&warm_avg, i),
        ]);
        i += step;
    }
    out.push_str(&t.render());

    // paired sample-efficiency: samples the warm run needs to match the
    // cold run's final best, over the samples the cold run took to get
    // there
    let mut fracs = Vec::new();
    let mut warm_wins = 0usize;
    let mut reached = 0usize;
    for (c, w) in cold_runs.iter().zip(&warm_runs) {
        let Some(cold_best) = c.best_cycles() else { continue };
        let cold_at = c.trials_to_reach(cold_best as f64).unwrap();
        match w.trials_to_reach(cold_best as f64) {
            Some(warm_at) => {
                reached += 1;
                if warm_at < cold_at {
                    warm_wins += 1;
                }
                fracs.push(warm_at as f64 / cold_at as f64);
            }
            None => fracs.push(f64::NAN),
        }
    }
    let finite: Vec<f64> =
        fracs.iter().copied().filter(|v| v.is_finite()).collect();
    if finite.is_empty() {
        out.push_str("\nwarm runs never reached the cold best within \
                      budget\n");
    } else {
        out.push_str(&format!(
            "\nwarm reaches the cold run's best cycles in {}/{} repeats, \
             using {:.1}% of the cold run's samples on average \
             (warm strictly fewer in {}/{})\n",
            reached,
            cold_runs.len(),
            100.0 * mean(&finite),
            warm_wins,
            cold_runs.len(),
        ));
    }
    let cold_final = mean(
        &cold_runs
            .iter()
            .filter_map(|t| t.best_cycles().map(|c| c as f64))
            .collect::<Vec<_>>(),
    );
    let warm_final = mean(
        &warm_runs
            .iter()
            .filter_map(|t| t.best_cycles().map(|c| c as f64))
            .collect::<Vec<_>>(),
    );
    out.push_str(&format!(
        "final best (mean): cold {} vs warm {} cycles\n",
        f(cold_final, 0),
        f(warm_final, 0)
    ));
    out
}
