//! Table 2(b): invalidity ratio of configurations per layer under random
//! sampling, side by side with the paper's board measurements.

use super::{data, ExpConfig};
use crate::util::table::{f, Table};
use crate::workloads::resnet18;

/// Render the Table 2(b) invalidity-ratio reproduction.
pub fn run(cfg: &ExpConfig) -> String {
    let limit = if cfg.quick { 400 } else { 2000 };
    let mut out = String::from(
        "== Table 2(b): invalidity ratio under random sampling ==\n\n",
    );
    let mut t = Table::new(&[
        "layer",
        "ours (sim)",
        "crash",
        "wrong-output",
        "paper (board)",
    ]);
    for (layer, (pname, pval)) in
        resnet18::LAYERS.iter().zip(resnet18::PAPER_INVALIDITY)
    {
        assert_eq!(layer.name, pname);
        let records =
            data::space_profile(&cfg.hw, layer, limit, cfg.seed);
        let n = records.len() as f64;
        let crash = records
            .iter()
            .filter(|r| {
                r.outcome == crate::tuner::database::Outcome::Crash
            })
            .count() as f64;
        let wrong = records
            .iter()
            .filter(|r| {
                r.outcome
                    == crate::tuner::database::Outcome::WrongOutput
            })
            .count() as f64;
        t.row(&[
            layer.name.to_string(),
            f((crash + wrong) / n, 4),
            f(crash / n, 4),
            f(wrong / n, 4),
            f(pval, 4),
        ]);
    }
    out.push_str(&t.render());
    out.push_str(
        "\n(ordering across layers should match the paper — conv1-class \
         layers hardest; absolute level is lower because the simulated \
         fault model is more regular than the authors' board, see \
         EXPERIMENTS.md)\n",
    );
    out
}
