//! Experiment harnesses — one per paper table/figure (EXPERIMENTS.md
//! records the results). Each prints the same rows/series the paper reports;
//! absolute values come from our simulated testbed, the paper's values are
//! shown alongside where the paper states them.
//!
//! `quick` mode shrinks repeats/budgets so the whole suite runs in minutes
//! (used by integration tests); full mode is what EXPERIMENTS.md records.

pub mod data;
pub mod fidelity;
pub mod fig2a;
pub mod fig2b;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod headline;
pub mod storm;
pub mod table2;
pub mod table4;
pub mod table5;
pub mod transfer;

use anyhow::{bail, Result};

use crate::vta::config::VtaConfig;

/// Shared experiment knobs.
#[derive(Clone, Debug)]
pub struct ExpConfig {
    /// Repeats for averaging (paper: 10).
    pub repeats: usize,
    /// Base RNG seed.
    pub seed: u64,
    /// Shrunk-scale run for tests.
    pub quick: bool,
    /// Hardware target every harness profiles on (`--target`; default
    /// the paper's zcu102, so recorded numbers regenerate unchanged).
    pub hw: VtaConfig,
    /// `experiment transfer --meta`: add a third arm that adapts from a
    /// corpus-trained meta artifact built over the source-layer logs
    /// (off by default so recorded numbers regenerate unchanged).
    pub meta: bool,
}

impl ExpConfig {
    /// Full-scale knobs — what EXPERIMENTS.md records.
    pub fn full() -> Self {
        ExpConfig { repeats: 10, seed: 2024, quick: false,
                    hw: VtaConfig::zcu102(), meta: false }
    }

    /// Shrunk knobs for integration tests and CI smoke runs.
    pub fn quick() -> Self {
        ExpConfig { repeats: 2, seed: 2024, quick: true,
                    hw: VtaConfig::zcu102(), meta: false }
    }
}

/// All experiment ids: the paper's tables/figures in paper order, then
/// the beyond-paper transfer warm-start and serving-storm studies.
pub const ALL: [&str; 12] = [
    "fig2a", "fig2b", "fig3", "fig4", "fig5", "table2", "table4", "table5",
    "headline", "transfer", "storm", "fidelity",
];

/// Dispatch an experiment by id; returns the printed report.
pub fn run(id: &str, cfg: &ExpConfig) -> Result<String> {
    let report = match id {
        "fig2a" => fig2a::run(cfg),
        "fig2b" => fig2b::run(cfg),
        "fig3" => fig3::run(cfg),
        "fig4" => fig4::run(cfg),
        "fig5" => fig5::run(cfg),
        "table2" => table2::run(cfg),
        "table4" => table4::run(cfg),
        "table5" => table5::run(cfg),
        "headline" => headline::run(cfg),
        "transfer" => transfer::run(cfg),
        "storm" => storm::run(cfg)?,
        "fidelity" => fidelity::run(cfg),
        other => bail!("unknown experiment '{other}'; known: {ALL:?}"),
    };
    println!("{report}");
    Ok(report)
}
