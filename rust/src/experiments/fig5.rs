//! Fig. 5 (Appendix B.3): per-layer tuning results for all of Conv1–Conv10
//! — ML²Tuner vs the TVM approach: best found, trials to reach parity,
//! invalidity ratios.

use super::{data, ExpConfig};
use crate::util::stats::mean;
use crate::util::table::{f, Table};
use crate::workloads::resnet18;

/// Render the Fig. 5 per-layer tuning comparison.
pub fn run(cfg: &ExpConfig) -> String {
    let (repeats, ml2_t, tvm_t) = if cfg.quick {
        (cfg.repeats.min(2), 100, 200)
    } else {
        (cfg.repeats.min(5), 300, 700)
    };
    let clock = cfg.hw.clock_mhz;
    let mut out = String::from(
        "== Fig 5: per-layer tuning results, ML2Tuner vs TVM approach ==\n\n",
    );
    let mut t = Table::new(&[
        "layer",
        "ml2 best (ms)",
        "tvm best (ms)",
        "samples vs tvm (%)",
        "ml2 invalid",
        "tvm invalid",
    ]);
    let mut effs = Vec::new();
    for layer in resnet18::LAYERS {
        let runs = data::compare_on_layer(&cfg.hw, layer.name,
                                          repeats, ml2_t, tvm_t,
                                          cfg.seed);
        let best_ms = |traces: &[crate::tuner::report::TuningTrace]| {
            let bests: Vec<f64> = traces
                .iter()
                .filter_map(|t| t.best_cycles())
                .map(|c| c as f64 / (clock * 1e3))
                .collect();
            mean(&bests)
        };
        let eff: Vec<f64> = runs
            .ml2
            .iter()
            .zip(&runs.tvm)
            .filter_map(|(m, t)| data::sample_efficiency(m, t, 100))
            .map(|e| e * 100.0)
            .collect();
        if !eff.is_empty() {
            effs.push(mean(&eff));
        }
        t.row(&[
            layer.name.to_string(),
            f(best_ms(&runs.ml2), 3),
            f(best_ms(&runs.tvm), 3),
            if eff.is_empty() { "-".into() } else { f(mean(&eff), 1) },
            f(data::mean_invalidity(&runs.ml2), 3),
            f(data::mean_invalidity(&runs.tvm), 3),
        ]);
    }
    out.push_str(&t.render());
    if !effs.is_empty() {
        out.push_str(&format!(
            "\naverage samples-to-TVM-parity: {:.1}% (paper: 12.3%)\n",
            mean(&effs)
        ));
    }
    out
}
