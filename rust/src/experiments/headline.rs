//! Headline metrics (paper §1/§3): "ML²Tuner achieves equivalent
//! performance improvements using only 12.3% of the samples required with
//! a similar approach as TVM and reduces invalid profiling attempts by an
//! average of 60.8%" — plus the estimated profiling wall-clock the
//! filtering saves (the paper's motivation).

use super::{data, ExpConfig};
use crate::tuner::report::ProfilingCostModel;
use crate::util::stats::mean;
use crate::util::table::{f, Table};
use crate::workloads::resnet18;

/// Render the headline sample-efficiency / invalid-avoided metrics.
pub fn run(cfg: &ExpConfig) -> String {
    let (repeats, ml2_t, tvm_t) = if cfg.quick {
        (cfg.repeats.min(2), 100, 200)
    } else {
        (cfg.repeats.min(5), 300, 700)
    };
    let cost = ProfilingCostModel::default();
    let mut out =
        String::from("== Headline metrics (paper §1/§3) ==\n\n");
    let mut t = Table::new(&[
        "layer",
        "samples vs tvm (%)",
        "ml2 invalid",
        "tvm invalid",
        "random invalid",
        "est. wall-clock save vs random",
    ]);
    let mut effs = Vec::new();
    let mut inv_ml2 = Vec::new();
    let mut inv_tvm = Vec::new();
    let mut inv_rnd = Vec::new();
    for layer in resnet18::LAYERS {
        let runs = data::compare_on_layer(&cfg.hw, layer.name,
                                          repeats, ml2_t, tvm_t,
                                          cfg.seed);
        let eff: Vec<f64> = runs
            .ml2
            .iter()
            .zip(&runs.tvm)
            .filter_map(|(m, t)| data::sample_efficiency(m, t, 100))
            .map(|e| e * 100.0)
            .collect();
        let (im, it, ir) = (
            data::mean_invalidity(&runs.ml2),
            data::mean_invalidity(&runs.tvm),
            data::mean_invalidity(&runs.random),
        );
        // wall-clock: same trial count (ml2 budget) for a fair rate compare
        let wc = |traces: &[crate::tuner::report::TuningTrace]| {
            mean(
                &traces
                    .iter()
                    .map(|t| t.estimated_wall_clock(&cost)
                        / t.len().max(1) as f64)
                    .collect::<Vec<_>>(),
            )
        };
        let save = 1.0 - wc(&runs.ml2) / wc(&runs.random).max(1e-9);
        if !eff.is_empty() {
            effs.push(mean(&eff));
        }
        inv_ml2.push(im);
        inv_tvm.push(it);
        inv_rnd.push(ir);
        t.row(&[
            layer.name.to_string(),
            if eff.is_empty() { "-".into() } else { f(mean(&eff), 1) },
            f(im, 3),
            f(it, 3),
            f(ir, 3),
            format!("{:.0}%", save * 100.0),
        ]);
    }
    out.push_str(&t.render());
    let red_vs_tvm = (1.0
        - mean(&inv_ml2) / mean(&inv_tvm).max(1e-9))
        * 100.0;
    let red_vs_rnd = (1.0
        - mean(&inv_ml2) / mean(&inv_rnd).max(1e-9))
        * 100.0;
    out.push_str(&format!(
        "\nsamples-to-TVM-parity (avg): {:.1}%   (paper: 12.3%)\n\
         invalid-attempt reduction vs TVM: {red_vs_tvm:.1}%   (paper: \
         60.8%)\n\
         invalid-attempt reduction vs random: {red_vs_rnd:.1}%\n\
         (our TVM baseline avoids invalids more easily than on the \
         authors' board — the simulated fault model is deterministic; \
         see EXPERIMENTS.md discussion)\n",
        mean(&effs)
    ));
    out
}
