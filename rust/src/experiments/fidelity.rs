//! Multi-fidelity ladder: end-to-end tuning cost at prescreen factors
//! {off, 2, 4, 8} (EXPERIMENTS.md §Multi-fidelity).
//!
//! Beyond-paper experiment for the tier-0 prescreen
//! ([`crate::vta::coarse`] + `--prescreen-factor`). Protocol:
//!
//! 1. for each pinned (network, layer) config and each repeat, run the
//!    full-fidelity baseline (`prescreen_factor = 0`) and one run per
//!    ladder rung (2, 4, 8) with the *same* seed — every rung of a
//!    repeat answers "what would this exact run have cost with the
//!    prescreen on";
//! 2. each run gets a fresh engine (cold compile cache) and is wall-
//!    clock timed end to end;
//! 3. report, per rung, the median tune time and speedup over the
//!    baseline, the mean best cycles, how many repeats matched the
//!    baseline's final best, and the median full-fidelity samples the
//!    rung needed to reach it.
//!
//! The per-rung time medians are also pushed through the standard
//! [`Bench`] sink (`ML2_BENCH_JSON`), so CI's bench-regression job
//! folds them into `BENCH_8.json` exactly like the `cargo bench`
//! suites.

use std::path::Path;
use std::time::{Duration, Instant};

use super::ExpConfig;
use crate::compiler::schedule::SpaceKind;
use crate::engine::Engine;
use crate::tuner::ml2tuner::Ml2Tuner;
use crate::tuner::report::TuningTrace;
use crate::tuner::{Tuner, TunerConfig, TuningEnv};
use crate::util::bench::{Bench, BenchResult};
use crate::util::stats::mean;
use crate::util::table::{f, Table};
use crate::workloads;

/// The ladder: prescreen off, then 2x / 4x / 8x over-selection.
const FACTORS: [usize; 4] = [0, 2, 4, 8];

/// Entry point for `ml2tuner experiment fidelity`; honours
/// `ML2_BENCH_JSON` for the medians sink.
pub fn run(cfg: &ExpConfig) -> String {
    let out = std::env::var("ML2_BENCH_JSON")
        .ok()
        .filter(|p| !p.is_empty());
    run_to(cfg, out.as_deref().map(Path::new))
}

/// Env-var-free body of [`run`] (what tests drive directly): when `out`
/// is given, per-rung time medians are appended there as `Bench` JSONL.
pub fn run_to(cfg: &ExpConfig, out: Option<&Path>) -> String {
    let (configs, trials): (&[(&str, &str)], usize) = if cfg.quick {
        (&[("resnet18", "conv5")], 40)
    } else {
        (&[("resnet18", "conv5"), ("vgg16", "conv3_1")], 150)
    };
    let mut bench = Bench::new();
    let mut report = format!(
        "== multi-fidelity ladder: prescreen factors {FACTORS:?} ==\n\
         ({} repeats x {} trials per rung, extended space, paired seeds, \
         fresh engine per run)\n",
        cfg.repeats, trials
    );

    for &(net_name, layer_name) in configs {
        let layer = workloads::network(net_name)
            .unwrap()
            .layer(layer_name)
            .unwrap();
        // per factor: wall times, final bests, and (matched, samples)
        let mut times: Vec<Vec<Duration>> =
            vec![Vec::new(); FACTORS.len()];
        let mut bests: Vec<Vec<f64>> = vec![Vec::new(); FACTORS.len()];
        let mut matched: Vec<usize> = vec![0; FACTORS.len()];
        let mut samples: Vec<Vec<f64>> = vec![Vec::new(); FACTORS.len()];
        let mut paired = 0usize;
        for r in 0..cfg.repeats {
            let seed = cfg.seed ^ (r as u64).wrapping_mul(0x9e37_79b9);
            let mut baseline_best: Option<u64> = None;
            for (fi, &factor) in FACTORS.iter().enumerate() {
                let t_cfg = TunerConfig {
                    seed,
                    max_trials: trials,
                    prescreen_factor: factor,
                    ..Default::default()
                };
                let env = TuningEnv::with_space(
                    cfg.hw.clone(),
                    layer,
                    SpaceKind::Extended,
                );
                let engine = Engine::default();
                let t0 = Instant::now();
                let trace =
                    Ml2Tuner::new(t_cfg).tune_with(&env, &engine);
                times[fi].push(t0.elapsed());
                if let Some(b) = trace.best_cycles() {
                    bests[fi].push(b as f64);
                }
                if factor == 0 {
                    baseline_best = trace.best_cycles();
                    paired += usize::from(baseline_best.is_some());
                } else if let Some(target) = baseline_best {
                    if let Some(at) = reach(&trace, target) {
                        matched[fi] += 1;
                        samples[fi].push(at as f64);
                    }
                }
            }
        }

        report.push_str(&format!(
            "\n-- {net_name}/{layer_name} --\n"
        ));
        let mut t = Table::new(&[
            "factor",
            "median tune s",
            "speedup",
            "best (mean cycles)",
            "matched best",
            "median samples-to-match",
        ]);
        let base_median = median_dur(&times[0]);
        for (fi, &factor) in FACTORS.iter().enumerate() {
            let med = median_dur(&times[fi]);
            let stats = dur_stats(
                &format!(
                    "fidelity/{net_name}_{layer_name}/factor_{factor}"
                ),
                &times[fi],
            );
            bench.results.push(stats);
            t.row(&[
                if factor == 0 {
                    "off".to_string()
                } else {
                    format!("{factor}x")
                },
                f(med.as_secs_f64(), 2),
                if factor == 0 {
                    "1.00x".to_string()
                } else {
                    format!(
                        "{:.2}x",
                        base_median.as_secs_f64() / med.as_secs_f64()
                    )
                },
                if bests[fi].is_empty() {
                    "-".to_string()
                } else {
                    f(mean(&bests[fi]), 0)
                },
                if factor == 0 {
                    format!("{paired}/{} (baseline)", cfg.repeats)
                } else {
                    format!("{}/{paired}", matched[fi])
                },
                if samples[fi].is_empty() {
                    "-".to_string()
                } else {
                    f(median_f64(&samples[fi]), 0)
                },
            ]);
        }
        report.push_str(&t.render());
    }
    report.push_str(
        "\n'matched best': repeats whose rung run reached the paired \
         baseline run's final best cycles within the same trial budget; \
         'samples-to-match' counts full-fidelity profilings only (the \
         trace never contains tier-0 estimates).\n",
    );
    if let Some(path) = out {
        bench.write_json_to("fidelity", path);
        report.push_str(&format!(
            "medians appended to {}\n",
            path.display()
        ));
    }
    report
}

/// First 1-based trial index at which `trace` reaches `target` cycles.
fn reach(trace: &TuningTrace, target: u64) -> Option<usize> {
    trace.trials_to_reach(target as f64)
}

fn median_dur(xs: &[Duration]) -> Duration {
    let mut s = xs.to_vec();
    s.sort();
    s[s.len() / 2]
}

fn median_f64(xs: &[f64]) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    s[s.len() / 2]
}

/// Fold one rung's wall times into a [`BenchResult`] row so the ladder
/// shares the `ML2_BENCH_JSON` → `bench_report.py` pipeline.
fn dur_stats(name: &str, xs: &[Duration]) -> BenchResult {
    let mut s = xs.to_vec();
    s.sort();
    let n = s.len();
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean: s.iter().sum::<Duration>() / n as u32,
        median: s[n / 2],
        p10: s[n / 10],
        p90: s[(n * 9 / 10).min(n - 1)],
        items_per_iter: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn quick_ladder_runs_and_writes_bench_lines() {
        let cfg = ExpConfig {
            repeats: 1,
            seed: 0xf1de,
            ..ExpConfig::quick()
        };
        let out = std::env::temp_dir()
            .join("ml2tuner_fidelity_bench_test.jsonl");
        std::fs::remove_file(&out).ok();
        let report = run_to(&cfg, Some(&out));
        assert!(report.contains("multi-fidelity ladder"));
        assert!(report.contains("factor"));
        let text = std::fs::read_to_string(&out).unwrap();
        std::fs::remove_file(&out).ok();
        let lines: Vec<&str> =
            text.lines().filter(|l| !l.is_empty()).collect();
        // one Bench row per (config, factor)
        assert_eq!(lines.len(), FACTORS.len());
        for line in lines {
            let j = Json::parse(line).unwrap();
            assert_eq!(
                j.get("suite").and_then(Json::as_str).unwrap(),
                "fidelity"
            );
            assert!(j
                .get("name")
                .and_then(Json::as_str)
                .unwrap()
                .starts_with("fidelity/resnet18_conv5/factor_"));
            assert!(
                j.get("median_ns").and_then(Json::as_u64).unwrap() > 0
            );
        }
    }
}
