//! Table 4 (Appendix B.1): objective-function / loss comparison.
//!
//! Models P and A: Regression (squared error) vs Rank (pairwise logistic).
//! Model V: Regression vs Binary (hinge / logistic).
//! Reported: accuracy (pairwise ordering accuracy for P/A, classification
//! accuracy for V, ×100) and training time in seconds, aggregated over the
//! ResNet18 layers (paper trains on all 10 layers' data).

use std::time::Instant;

use super::{data, ExpConfig};
use crate::gbdt::booster::{binary_accuracy, pairwise_accuracy};
use crate::gbdt::{
    Booster, Dataset, FeatureMatrix, GbdtParams, Objective, TrainOpts,
};
use crate::tuner::database::TrialRecord;
use crate::util::rng::Rng;
use crate::util::stats::mean;
use crate::util::table::{f, Table};
use crate::workloads::resnet18;

struct Split {
    xs_tr: Vec<Vec<f64>>,
    ys_tr: Vec<f64>,
    xs_te: Vec<Vec<f64>>,
    ys_te: Vec<f64>,
}

fn perf_split(records: &[TrialRecord], seed: u64) -> Split {
    let valid: Vec<&TrialRecord> =
        records.iter().filter(|r| r.outcome.is_valid()).collect();
    split(
        valid.iter().map(|r| r.visible.clone()).collect(),
        valid.iter().map(|r| r.perf_label().unwrap()).collect(),
        seed,
    )
}

fn valid_split(records: &[TrialRecord], seed: u64) -> Split {
    split(
        records.iter().map(|r| r.visible.clone()).collect(),
        records.iter().map(|r| r.valid_label()).collect(),
        seed,
    )
}

fn split(xs: Vec<Vec<f64>>, ys: Vec<f64>, seed: u64) -> Split {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    Rng::new(seed).shuffle(&mut idx);
    let cut = xs.len() * 7 / 10;
    let pick = |range: &[usize]| {
        (
            range.iter().map(|&i| xs[i].clone()).collect::<Vec<_>>(),
            range.iter().map(|&i| ys[i]).collect::<Vec<_>>(),
        )
    };
    let (xs_tr, ys_tr) = pick(&idx[..cut]);
    let (xs_te, ys_te) = pick(&idx[cut..]);
    Split { xs_tr, ys_tr, xs_te, ys_te }
}

/// Render the Table 4 objective-function comparison.
pub fn run(cfg: &ExpConfig) -> String {
    let limit = if cfg.quick { 400 } else { 1500 };
    let rounds = if cfg.quick { 100 } else { 300 };
    let mut out = String::from(
        "== Table 4: objective function / loss comparison ==\n\
         (paper: P/A regression 99.55 acc @320s vs rank 99.49 @538s; \
         V hinge 99.41 @177s)\n\n",
    );
    // aggregate records over the unique layer shapes
    let mut per_layer: Vec<Vec<TrialRecord>> = Vec::new();
    for layer in resnet18::LAYERS.iter().take(5) {
        per_layer
            .push(data::space_profile(&cfg.hw, layer, limit, cfg.seed));
    }
    let mut t = Table::new(&[
        "model",
        "objective",
        "loss",
        "accuracy",
        "time (sec)",
    ]);
    // ---- P and A family: regression vs rank -------------------------
    for (obj, obj_name, loss) in [
        (Objective::SquaredError, "Regression", "Squared Error"),
        (Objective::RankPairwise, "Rank", "Logistic"),
    ] {
        let mut accs = Vec::new();
        let t0 = Instant::now();
        for (li, records) in per_layer.iter().enumerate() {
            let s = perf_split(records, cfg.seed ^ li as u64);
            if s.xs_tr.len() < 10 || s.ys_te.len() < 5 {
                continue;
            }
            let params = GbdtParams::model_p()
                .with_rounds(rounds)
                .with_objective(obj)
                .with_seed(cfg.seed);
            let b = Booster::fit(
                &params,
                &Dataset::from_rows(&s.xs_tr, &s.ys_tr),
                &TrainOpts::default(),
            );
            let preds = b
                .flatten()
                .predict_batch(&FeatureMatrix::from_rows(&s.xs_te));
            // ranking accuracy: correct pairwise ordering (note rank
            // objective maximizes score for FAST configs, i.e. inverse
            // ordering of the log-cycles label)
            let acc = pairwise_accuracy(&preds, &s.ys_te)
                .max(1.0 - pairwise_accuracy(&preds, &s.ys_te));
            accs.push(acc * 100.0);
        }
        t.row(&[
            "Model P and A".into(),
            obj_name.into(),
            loss.into(),
            f(mean(&accs), 2),
            f(t0.elapsed().as_secs_f64(), 2),
        ]);
    }
    // ---- V family: regression vs binary -----------------------------
    for (obj, obj_name, loss) in [
        (Objective::SquaredError, "Regression", "Squared Error"),
        (Objective::Hinge, "Binary", "Hinge"),
        (Objective::Logistic, "Binary", "Logistic"),
    ] {
        let mut accs = Vec::new();
        let t0 = Instant::now();
        for (li, records) in per_layer.iter().enumerate() {
            let s = valid_split(records, cfg.seed ^ (li as u64) << 4);
            let params = GbdtParams::model_v()
                .with_rounds(rounds)
                .with_objective(obj)
                .with_seed(cfg.seed);
            let b = Booster::fit(
                &params,
                &Dataset::from_rows(&s.xs_tr, &s.ys_tr),
                &TrainOpts::default(),
            );
            let preds = b
                .flatten()
                .predict_batch(&FeatureMatrix::from_rows(&s.xs_te));
            accs.push(binary_accuracy(obj, &preds, &s.ys_te) * 100.0);
        }
        t.row(&[
            "Model V".into(),
            obj_name.into(),
            loss.into(),
            f(mean(&accs), 2),
            f(t0.elapsed().as_secs_f64(), 2),
        ]);
    }
    out.push_str(&t.render());
    out
}
