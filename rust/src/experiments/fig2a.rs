//! Fig. 2(a): tuning curves for Conv1 and Conv2 — lowest execution time
//! among cumulative configurations vs number of configurations tested,
//! ML²Tuner (orange in the paper) vs the TVM approach (blue), averaged
//! over repeats.

use super::{data, ExpConfig};
use crate::tuner::report::average_curves;
use crate::util::table::{ascii_curve, f, Table};

/// Render the Fig. 2(a) tuning-curve reproduction.
pub fn run(cfg: &ExpConfig) -> String {
    let (repeats, ml2_t, tvm_t) = if cfg.quick {
        (cfg.repeats, 120, 240)
    } else {
        (cfg.repeats, 300, 800)
    };
    let clock = cfg.hw.clock_mhz;
    let to_ms = |c: f64| c / (clock * 1e3);
    let mut out = String::from(
        "== Fig 2(a): best-so-far execution time vs configurations \
         tested ==\n(averaged best-so-far, ms; paper shows Conv1 and \
         Conv2)\n\n",
    );
    for layer in ["conv1", "conv2"] {
        let runs = data::compare_on_layer(&cfg.hw, layer, repeats,
                                          ml2_t, tvm_t, cfg.seed);
        let ml2_avg = average_curves(
            &runs.ml2.iter().map(|t| t.best_curve()).collect::<Vec<_>>(),
        );
        let tvm_avg = average_curves(
            &runs.tvm.iter().map(|t| t.best_curve()).collect::<Vec<_>>(),
        );
        out.push_str(&format!("--- {layer} ({repeats} repeats) ---\n"));
        let mut t = Table::new(&[
            "configs tested",
            "ML2Tuner best (ms)",
            "TVM best (ms)",
        ]);
        let step = if cfg.quick { 20 } else { 50 };
        let max_len = tvm_avg.len().max(ml2_avg.len());
        let cell = |curve: &[f64], i: usize| {
            let idx = i.min(curve.len().saturating_sub(1));
            let v = curve.get(idx).copied().unwrap_or(f64::INFINITY);
            if v.is_finite() {
                f(to_ms(v), 3)
            } else {
                "-".to_string()
            }
        };
        let mut i = step - 1;
        while i < max_len {
            t.row(&[
                (i + 1).to_string(),
                cell(&ml2_avg, i),
                cell(&tvm_avg, i),
            ]);
            i += step;
        }
        out.push_str(&t.render());
        out.push_str("\nML2Tuner curve:\n");
        let finite: Vec<f64> = ml2_avg
            .iter()
            .map(|&v| to_ms(v.min(1e12)))
            .collect();
        out.push_str(&ascii_curve(&finite, 60, 8));
        // paper-style sample-efficiency callout per layer
        let effs: Vec<f64> = runs
            .ml2
            .iter()
            .zip(&runs.tvm)
            .filter_map(|(m, t)| data::sample_efficiency(m, t, 100))
            .collect();
        if !effs.is_empty() {
            out.push_str(&format!(
                "\n{layer}: ML2Tuner reaches the TVM-converged best with \
                 {:.1}% of TVM's samples (paper: Conv1 11.2%, Conv3 \
                 11.3%, avg 12.3%)\n\n",
                100.0 * crate::util::stats::mean(&effs)
            ));
        }
    }
    out
}
