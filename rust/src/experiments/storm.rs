//! Serving storm: schedule-db lookup latency under a mixed hit/miss
//! query flood (EXPERIMENTS.md §Serving).
//!
//! Protocol:
//!
//! 1. seed a throwaway [`ScheduleDb`] with one synthetic best-schedule
//!    entry per (layer shape, codegen signature) across every registered
//!    network and target, in the paper knob space;
//! 2. pre-render ≥ 1000 query request lines — two thirds against seeded
//!    keys (hits), one third against the same shapes in the extended
//!    space (misses), deterministically shuffled;
//! 3. drive each line through the daemon's synchronous answer path
//!    (request parse + registry resolution + key build + in-memory
//!    lookup) and record per-query wall latency.
//!
//! Reported: p50 / p99 / mean per class (all, hit, miss) plus the
//! daemon's hit/miss counters, which must account for every query.
//! Where the paper frames savings as invalid profilings avoided,
//! serving frames them as whole *tunings* avoided: a hit replaces an
//! entire tuning run with a microsecond-scale map probe. With
//! `ML2_STORM_JSON=<path>` set (CI's smoke-serve job), the percentiles
//! are also written as a `BENCH_7.json`-style medians file for the
//! bench-regression promotion flow.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::ExpConfig;
use crate::compiler::schedule::{Schedule, SpaceKind};
use crate::obs::Counter;
use crate::serve::{
    Daemon, Request, ScheduleDb, ScheduleEntry, ScheduleKey, ServeConfig,
};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::table::Table;
use crate::vta::targets;
use crate::workloads;

/// Entry point for `ml2tuner experiment storm`; honours
/// `ML2_STORM_JSON` for the medians file.
pub fn run(cfg: &ExpConfig) -> Result<String> {
    let out = std::env::var("ML2_STORM_JSON")
        .ok()
        .filter(|p| !p.is_empty());
    run_to(cfg, out.as_deref().map(Path::new))
}

/// Env-var-free body of [`run`] (what tests and CI drive directly):
/// when `out` is given, the percentile summary is written there as a
/// `BENCH_7.json`-style medians file.
pub fn run_to(cfg: &ExpConfig, out: Option<&Path>) -> Result<String> {
    let n_queries = if cfg.quick { 1_200 } else { 10_000 };
    let dir = std::env::temp_dir()
        .join(format!("ml2tuner_storm_{}", cfg.seed));
    if dir.exists() {
        std::fs::remove_dir_all(&dir).ok();
    }
    let db = ScheduleDb::open(&dir)?;

    // -- 1. seed synthetic best entries (paper space = the hit set) ---
    let mut hit_lines: Vec<String> = Vec::new();
    let mut miss_lines: Vec<String> = Vec::new();
    for net in &workloads::NETWORKS {
        for layer in net.layers {
            for hw in targets::all() {
                let key = ScheduleKey::for_layer_on(
                    layer,
                    SpaceKind::Paper,
                    &hw,
                );
                // synthetic but deterministic "best" — storm measures
                // lookup latency, not schedule quality
                db.promote(ScheduleEntry {
                    key,
                    version: 0,
                    cycles: layer.macs() / 8 + key.hash64() % 997 + 1,
                    schedule: Schedule::default(),
                    layer: layer.name.to_string(),
                    target: hw.target.clone(),
                    tuner: "storm-seed".to_string(),
                    trials: 1,
                })?;
                hit_lines.push(query_line(
                    net.name, layer.name, &hw.target, "paper",
                ));
                // same shapes, unseeded space → guaranteed miss
                miss_lines.push(query_line(
                    net.name, layer.name, &hw.target, "extended",
                ));
            }
        }
    }

    // -- 2. mixed query stream, deterministically shuffled ------------
    let mut rng = Rng::new(cfg.seed ^ 0x5708_31a7);
    let mut stream: Vec<(bool, String)> = Vec::with_capacity(n_queries);
    for i in 0..n_queries {
        let hit = i % 3 != 2; // two thirds hits
        let pool = if hit { &hit_lines } else { &miss_lines };
        stream.push((hit, pool[rng.below(pool.len())].clone()));
    }
    rng.shuffle(&mut stream);

    // -- 3. drive the synchronous answer path, timing each query ------
    let n_entries = db.len();
    let daemon = Daemon::new(ServeConfig::default(), Arc::new(db));
    let mut hit_ns: Vec<u64> = Vec::new();
    let mut miss_ns: Vec<u64> = Vec::new();
    for (expect_hit, line) in &stream {
        let t = Instant::now();
        let req = Request::parse(line).map_err(|e| {
            anyhow::anyhow!("storm query rejected: {}", e.message)
        })?;
        let Request::Query(q) = req else {
            bail!("storm line parsed as a non-query request");
        };
        let key = ScheduleKey::for_layer_on(&q.layer, q.space, &q.target);
        let found = std::hint::black_box(daemon.answer_lookup(&key));
        let ns = t.elapsed().as_nanos() as u64;
        if found.is_some() != *expect_hit {
            bail!("storm hit/miss expectation violated for: {line}");
        }
        if found.is_some() {
            hit_ns.push(ns);
        } else {
            miss_ns.push(ns);
        }
    }
    std::fs::remove_dir_all(&dir).ok();

    // -- 4. percentiles + counter cross-check -------------------------
    let mut all_ns: Vec<u64> =
        hit_ns.iter().chain(&miss_ns).copied().collect();
    all_ns.sort_unstable();
    hit_ns.sort_unstable();
    miss_ns.sort_unstable();
    let snap = daemon.recorder().snapshot();
    let (c_hits, c_misses) = (
        snap.counter(Counter::ScheduleDbHit),
        snap.counter(Counter::ScheduleDbMiss),
    );
    if c_hits != hit_ns.len() as u64 || c_misses != miss_ns.len() as u64 {
        bail!(
            "daemon counters disagree with observed outcomes: \
             {c_hits}/{c_misses} vs {}/{}",
            hit_ns.len(),
            miss_ns.len()
        );
    }

    let mut report = format!(
        "== serving storm: {n_queries} queries over a {n_entries}-entry \
         schedule db ==\n(per-query path: request parse + registry \
         resolution + key build + lookup)\n\n"
    );
    let classes: [(&str, &[u64]); 3] = [
        ("all", &all_ns),
        ("hit", &hit_ns),
        ("miss", &miss_ns),
    ];
    let mut t =
        Table::new(&["class", "queries", "p50 µs", "p99 µs", "mean µs"]);
    for (name, ns) in classes {
        t.row(&[
            name.to_string(),
            ns.len().to_string(),
            us(pct(ns, 0.50)),
            us(pct(ns, 0.99)),
            us(mean_ns(ns)),
        ]);
    }
    report.push_str(&t.render());
    report.push_str(&format!(
        "\ncounters: {c_hits} schedule_db_hits, {c_misses} \
         schedule_db_misses (every query accounted for)\n\
         each hit answered a best-schedule request with zero \
         compilation and zero profiling\n"
    ));

    // -- 5. optional BENCH_7.json-style medians file -------------------
    if let Some(path) = out {
        let mut benches = Json::obj();
        for (name, ns) in [
            ("storm/lookup_all", &all_ns),
            ("storm/lookup_hit", &hit_ns),
            ("storm/lookup_miss", &miss_ns),
        ] {
            let mut b = Json::obj();
            b.set("median_ns", pct(ns, 0.50))
                .set("mean_ns", mean_ns(ns))
                .set("iters", ns.len())
                .set("p50_ns", pct(ns, 0.50))
                .set("p99_ns", pct(ns, 0.99));
            benches.set(name, b);
        }
        let mut o = Json::obj();
        o.set("schema", 1)
            .set(
                "note",
                "Measured serving-storm lookup latencies (experiment \
                 storm). Regenerated by CI's smoke-serve job; promote \
                 with scripts/bench_report.py --update-baseline.",
            )
            .set("queries", n_queries)
            .set("benches", benches);
        std::fs::write(path, format!("{}\n", o.to_string_pretty()))
            .with_context(|| {
                format!("writing storm medians to {}", path.display())
            })?;
        report.push_str(&format!(
            "medians written to {}\n",
            path.display()
        ));
    }
    Ok(report)
}

fn query_line(net: &str, layer: &str, target: &str, space: &str) -> String {
    format!(
        "{{\"op\":\"query\",\"id\":1,\"network\":\"{net}\",\
         \"layer\":\"{layer}\",\"target\":\"{target}\",\
         \"space\":\"{space}\"}}"
    )
}

/// Nearest-rank percentile of an ascending-sorted sample.
fn pct(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn mean_ns(xs: &[u64]) -> u64 {
    if xs.is_empty() {
        return 0;
    }
    (xs.iter().sum::<u64>() as f64 / xs.len() as f64) as u64
}

fn us(ns: u64) -> String {
    format!("{:.2}", ns as f64 / 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_storm_runs_and_writes_medians() {
        let cfg = ExpConfig {
            seed: 0xd15c0,
            ..ExpConfig::quick()
        };
        let out =
            std::env::temp_dir().join("ml2tuner_storm_medians_test.json");
        std::fs::remove_file(&out).ok();
        let report = run_to(&cfg, Some(&out)).unwrap();
        assert!(report.contains("schedule_db_hits"));
        let text = std::fs::read_to_string(&out).unwrap();
        std::fs::remove_file(&out).ok();
        let j = Json::parse(&text).unwrap();
        assert!(
            j.get("queries").and_then(Json::as_usize).unwrap() >= 1000
        );
        let b = j.at(&["benches", "storm/lookup_all"]).unwrap();
        assert!(b.get("p99_ns").and_then(Json::as_u64).unwrap() > 0);
        assert_eq!(
            b.get("iters").and_then(Json::as_usize).unwrap(),
            j.get("queries").and_then(Json::as_usize).unwrap()
        );
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<u64> = (1..=100).collect();
        assert_eq!(pct(&xs, 0.0), 1);
        assert_eq!(pct(&xs, 1.0), 100);
        assert_eq!(pct(&xs, 0.50), 51); // round((n-1)*0.5) = 50 → xs[50]
        assert_eq!(pct(&[], 0.5), 0);
    }
}
