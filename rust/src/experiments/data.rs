//! Shared experiment data: full/sampled space profiles and tuning-run
//! bundles, with an in-process cache keyed by layer shape (the paper's
//! Table 2a repeats shapes; profiling is deterministic, so duplicates are
//! free).

use std::collections::HashMap;
use std::sync::Mutex;

use crate::engine::{Engine, EngineConfig};
use crate::tuner::database::TrialRecord;
use crate::tuner::ml2tuner::Ml2Tuner;
use crate::tuner::random_baseline::RandomTuner;
use crate::tuner::report::TuningTrace;
use crate::tuner::tvm_baseline::TvmTuner;
use crate::tuner::{Tuner, TunerConfig, TuningEnv};
use crate::util::rng::Rng;
use crate::vta::config::VtaConfig;
use crate::workloads::{resnet18, ConvLayer};

/// Deterministically profile up to `limit` configurations of a layer's
/// space (uniform subsample when the space is larger) on hardware `hw`.
/// Cached per (target, shape, limit).
pub fn space_profile(
    hw: &VtaConfig,
    layer: &ConvLayer,
    limit: usize,
    seed: u64,
) -> Vec<TrialRecord> {
    static CACHE: Mutex<Option<HashMap<String, Vec<TrialRecord>>>> =
        Mutex::new(None);
    // the hardware key is the full config debug repr, not the target
    // name: records depend on every capacity AND timing field, and two
    // (future, file-defined) configs could share a name while differing
    // in parameters — aliasing them would hand back records profiled on
    // the wrong hardware
    let key = format!(
        "{hw:?}-h{}w{}c{}kc{}kh{}kw{}p{}s{}-{limit}-{seed}",
        layer.h, layer.w, layer.c, layer.kc, layer.kh, layer.kw,
        layer.pad, layer.stride
    );
    {
        let guard = CACHE.lock().unwrap();
        if let Some(map) = guard.as_ref() {
            if let Some(v) = map.get(&key) {
                return v.clone();
            }
        }
    }
    let env = TuningEnv::new(hw.clone(), *layer);
    let n = env.space.len();
    let indices: Vec<usize> = if n <= limit {
        (0..n).collect()
    } else {
        let mut rng = Rng::new(seed ^ 0xda7a);
        rng.sample_indices(n, limit)
    };
    // batched profiling across all cores (order-preserving, so the
    // cached records are identical to a sequential profile); compile
    // caching is off — a sweep profiles every index exactly once, and
    // retaining the programs would only cost memory
    let engine = Engine::new(EngineConfig {
        max_cache_cost: 0,
        ..EngineConfig::default()
    });
    let records = engine.profile_batch(&env, &indices);
    let mut guard = CACHE.lock().unwrap();
    guard
        .get_or_insert_with(HashMap::new)
        .insert(key, records.clone());
    records
}

/// One repeated tuning comparison on a layer: (ml2tuner, tvm, random)
/// traces per repeat.
pub struct ComparisonRuns {
    /// The compared layer.
    pub layer: ConvLayer,
    /// One ML²Tuner trace per repeat.
    pub ml2: Vec<TuningTrace>,
    /// One TVM-baseline trace per repeat.
    pub tvm: Vec<TuningTrace>,
    /// One random-baseline trace per repeat.
    pub random: Vec<TuningTrace>,
}

/// Run the three tuners `repeats` times each (different seeds) with the
/// given budgets (paper: N=10, α=1, 10 repeats, averaged) on hardware
/// `hw`.
pub fn compare_on_layer(
    hw: &VtaConfig,
    layer_name: &str,
    repeats: usize,
    ml2_trials: usize,
    tvm_trials: usize,
    seed: u64,
) -> ComparisonRuns {
    let layer = resnet18::layer(layer_name).expect("layer");
    let env = TuningEnv::new(hw.clone(), layer);
    // one engine for all repeats/tuners: the compile cache carries over
    // (profiling is deterministic, so sharing it never changes a trace)
    let engine = Engine::default();
    let mut runs = ComparisonRuns {
        layer,
        ml2: Vec::new(),
        tvm: Vec::new(),
        random: Vec::new(),
    };
    for r in 0..repeats {
        let s = seed ^ (r as u64).wrapping_mul(0x9e37_79b9);
        let cfg = TunerConfig { seed: s, ..Default::default() };
        runs.ml2.push(
            Ml2Tuner::new(cfg.clone().with_trials(ml2_trials))
                .tune_with(&env, &engine),
        );
        runs.tvm.push(
            TvmTuner::new(cfg.clone().with_trials(tvm_trials))
                .tune_with(&env, &engine),
        );
        runs.random.push(
            RandomTuner::new(cfg.with_trials(tvm_trials))
                .tune_with(&env, &engine),
        );
    }
    runs
}

/// Mean invalidity ratio across traces.
pub fn mean_invalidity(traces: &[TuningTrace]) -> f64 {
    crate::util::stats::mean(
        &traces.iter().map(|t| t.invalidity_ratio()).collect::<Vec<_>>(),
    )
}

/// Paper's sample-efficiency metric for one repeat pair: trials ML²Tuner
/// needs to reach the TVM run's converged best, over TVM's trials to
/// converge. `None` when ML²Tuner never reaches the target.
pub fn sample_efficiency(
    ml2: &TuningTrace,
    tvm: &TuningTrace,
    window: usize,
) -> Option<f64> {
    let (tvm_trials, tvm_best) = tvm.convergence(window)?;
    let ml2_trials = ml2.trials_to_reach(tvm_best)?;
    Some(ml2_trials as f64 / tvm_trials as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn space_profile_cached_and_deterministic() {
        let hw = VtaConfig::zcu102();
        let layer = resnet18::layer("conv5").unwrap();
        let a = space_profile(&hw, &layer, 50, 1);
        let b = space_profile(&hw, &layer, 50, 1);
        assert_eq!(a.len(), 50);
        assert_eq!(a[0].space_index, b[0].space_index);
        // shape-duplicate layer hits the same cache entry
        let layer2 = resnet18::layer("conv6").unwrap();
        let c = space_profile(&hw, &resnet18::layer("conv2").unwrap(),
                              50, 1);
        let d = space_profile(&hw, &layer2, 50, 1);
        assert_eq!(c[0].space_index, d[0].space_index);
        // same shape on a different target is a different profile
        // entry (the key carries the target name)
        let e = space_profile(&VtaConfig::zcu104(), &layer, 50, 1);
        assert_eq!(e.len(), 50);
        assert_eq!(a[0].space_index, e[0].space_index,
                   "index stream is target-independent");
    }

    #[test]
    fn comparison_runs_shape() {
        let runs = compare_on_layer(&VtaConfig::zcu102(), "conv5", 2, 30,
                                    30, 7);
        assert_eq!(runs.ml2.len(), 2);
        assert_eq!(runs.tvm.len(), 2);
        assert_eq!(runs.random.len(), 2);
        assert!(runs.ml2.iter().all(|t| t.len() == 30));
    }
}
