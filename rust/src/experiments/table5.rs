//! Table 5 (Appendix B.2): normalized feature-importance scores of model A
//! across the layers — visible features (TW, TH, nVirtualThread, …) vs
//! hidden features (resolved tile geometry, dummy regions, branch flags).

use super::{data, ExpConfig};
use crate::compiler::features::combined_names;
use crate::compiler::schedule::SpaceKind;
use crate::gbdt::{Booster, Dataset, GbdtParams, TrainOpts};
use crate::tuner::database::TrialRecord;
use crate::util::stats::geomean;
use crate::util::table::{f, Table};
use crate::workloads::resnet18;

fn importance_for(records: &[TrialRecord], rounds: usize, seed: u64)
    -> Option<Vec<f64>>
{
    let valid: Vec<&TrialRecord> =
        records.iter().filter(|r| r.outcome.is_valid()).collect();
    if valid.len() < 30 {
        return None;
    }
    let xs: Vec<Vec<f64>> = valid
        .iter()
        .map(|r| {
            crate::compiler::features::combined_features(
                &r.visible, &r.hidden,
            )
        })
        .collect();
    let ys: Vec<f64> =
        valid.iter().map(|r| r.perf_label().unwrap()).collect();
    let params = GbdtParams::model_a().with_rounds(rounds).with_seed(seed);
    let b = Booster::fit(&params, &Dataset::from_rows(&xs, &ys),
                         &TrainOpts::default());
    Some(b.feature_importance())
}

/// Render the Table 5 feature-importance reproduction.
pub fn run(cfg: &ExpConfig) -> String {
    let (limit, rounds) = if cfg.quick { (500, 100) } else { (2500, 300) };
    // the experiment reproduces the paper's table: paper feature layout
    let names = combined_names(SpaceKind::Paper);
    let n_visible = SpaceKind::Paper.n_visible();
    let layers: Vec<_> = if cfg.quick {
        vec![resnet18::layer("conv1").unwrap(),
             resnet18::layer("conv4").unwrap()]
    } else {
        resnet18::LAYERS.to_vec()
    };
    let mut per_layer: Vec<(String, Vec<f64>)> = Vec::new();
    for layer in &layers {
        let records =
            data::space_profile(&cfg.hw, layer, limit, cfg.seed);
        if let Some(imp) = importance_for(&records, rounds, cfg.seed) {
            per_layer.push((layer.name.to_string(), imp));
        }
    }
    // geometric average across layers (paper's GeoAVG column)
    let geo: Vec<f64> = (0..names.len())
        .map(|fi| {
            geomean(
                &per_layer
                    .iter()
                    .map(|(_, imp)| imp[fi].max(1e-3))
                    .collect::<Vec<_>>(),
            )
        })
        .collect();
    let mut order: Vec<usize> = (0..names.len()).collect();
    order.sort_by(|&a, &b| geo[b].partial_cmp(&geo[a]).unwrap());

    let mut out = String::from(
        "== Table 5: normalized feature importance of model A (%) ==\n\
         ([v] = visible feature, [h] = hidden feature; paper: TW/TH \
         dominate, hidden features like nFilterInLoop and sizeOutTile* \
         follow)\n\n",
    );
    let mut header: Vec<String> =
        vec!["feature".into(), "GeoAVG".into()];
    header.extend(per_layer.iter().map(|(n, _)| n.clone()));
    let hdr: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut t = Table::new(&hdr);
    for &fi in &order {
        if geo[fi] < 0.01 {
            continue;
        }
        let kind = if fi < n_visible { "[v]" } else { "[h]" };
        let mut row =
            vec![format!("{kind} {}", names[fi]), f(geo[fi], 3)];
        row.extend(per_layer.iter().map(|(_, imp)| f(imp[fi], 3)));
        t.row(&row);
    }
    out.push_str(&t.render());
    let hidden_share: f64 = (n_visible..names.len())
        .map(|fi| geo[fi])
        .sum::<f64>()
        / geo.iter().sum::<f64>()
        * 100.0;
    out.push_str(&format!(
        "\nhidden-feature share of total importance (geo): {hidden_share:.1}%\n"
    ));
    out
}
