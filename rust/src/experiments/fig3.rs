//! Fig. 3: ratio of RMSE values of model A compared to model P across the
//! ResNet18 layers (paper: average 0.919 — A predicts better thanks to the
//! hidden features).

use super::{data, ExpConfig};
use crate::compiler::features::combined_features;
use crate::gbdt::{Booster, Dataset, GbdtParams, TrainOpts};
use crate::tuner::database::TrialRecord;
use crate::util::rng::Rng;
use crate::util::stats::{geomean, mean, rmse};
use crate::util::table::{f, Table};
use crate::workloads::resnet18;

/// Train P and A on a split of `records` and return (rmse_p, rmse_a) on
/// the held-out valid rows.
pub fn rmse_pair(
    records: &[TrialRecord],
    rounds: usize,
    train_n: usize,
    seed: u64,
) -> Option<(f64, f64)> {
    let valid: Vec<&TrialRecord> =
        records.iter().filter(|r| r.outcome.is_valid()).collect();
    if valid.len() < 20 {
        return None;
    }
    let mut idx: Vec<usize> = (0..valid.len()).collect();
    let mut rng = Rng::new(seed ^ 0xf16_3);
    rng.shuffle(&mut idx);
    let train_n = train_n.min(idx.len() * 7 / 10);
    let (tr, te) = idx.split_at(train_n);
    if te.is_empty() {
        return None;
    }
    let label = |r: &TrialRecord| r.perf_label().unwrap();
    let params = GbdtParams::model_p().with_rounds(rounds).with_seed(seed);
    // model P: visible features
    let xp: Vec<Vec<f64>> =
        tr.iter().map(|&i| valid[i].visible.clone()).collect();
    let yp: Vec<f64> = tr.iter().map(|&i| label(valid[i])).collect();
    let p = Booster::fit(&params, &Dataset::from_rows(&xp, &yp),
                         &TrainOpts::default());
    // model A: visible ⊕ hidden
    let xa: Vec<Vec<f64>> = tr
        .iter()
        .map(|&i| combined_features(&valid[i].visible, &valid[i].hidden))
        .collect();
    let a = Booster::fit(&params, &Dataset::from_rows(&xa, &yp),
                         &TrainOpts::default());
    let y_te: Vec<f64> = te.iter().map(|&i| label(valid[i])).collect();
    let pred_p: Vec<f64> = te
        .iter()
        .map(|&i| p.predict_row(&valid[i].visible))
        .collect();
    let pred_a: Vec<f64> = te
        .iter()
        .map(|&i| {
            a.predict_row(&combined_features(
                &valid[i].visible,
                &valid[i].hidden,
            ))
        })
        .collect();
    Some((rmse(&pred_p, &y_te), rmse(&pred_a, &y_te)))
}

/// Render the Fig. 3 RMSE-ratio reproduction.
pub fn run(cfg: &ExpConfig) -> String {
    let (limit, rounds, train_n) =
        if cfg.quick { (500, 100, 150) } else { (3000, 300, 600) };
    let mut out = String::from(
        "== Fig 3: RMSE(model A) / RMSE(model P) per layer ==\n\
         (paper: average ratio 0.919; < 1 means hidden features help)\n\n",
    );
    let mut t = Table::new(&["layer", "RMSE P", "RMSE A", "ratio A/P"]);
    let mut ratios = Vec::new();
    for layer in resnet18::LAYERS {
        let records =
            data::space_profile(&cfg.hw, &layer, limit, cfg.seed);
        let mut rp = Vec::new();
        let mut ra = Vec::new();
        for r in 0..cfg.repeats {
            if let Some((p, a)) =
                rmse_pair(&records, rounds, train_n, cfg.seed ^ r as u64)
            {
                rp.push(p);
                ra.push(a);
            }
        }
        if rp.is_empty() {
            continue;
        }
        let (mp, ma) = (mean(&rp), mean(&ra));
        ratios.push(ma / mp);
        t.row(&[
            layer.name.to_string(),
            f(mp, 4),
            f(ma, 4),
            f(ma / mp, 3),
        ]);
    }
    out.push_str(&t.render());
    out.push_str(&format!(
        "\naverage ratio: {:.3} (geomean {:.3}); paper reports 0.919\n",
        mean(&ratios),
        geomean(&ratios)
    ));
    out
}
