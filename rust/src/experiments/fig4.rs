//! Fig. 4 (Appendix B.3): RMSE(A)/RMSE(P) per layer as a function of the
//! number of configuration samples and the XGBoost boosting rounds
//! (100 vs 300). Paper: more rounds help (avg test accuracy 0.916 → 0.932),
//! and A beats P across most sample counts.

use super::{data, fig3::rmse_pair, ExpConfig};
use crate::util::stats::mean;
use crate::util::table::{f, Table};
use crate::workloads::resnet18;

/// Render the Fig. 4 samples-vs-rounds RMSE study.
pub fn run(cfg: &ExpConfig) -> String {
    let limit = if cfg.quick { 600 } else { 3000 };
    let sample_counts: &[usize] =
        if cfg.quick { &[50, 150] } else { &[50, 100, 200, 400, 800] };
    let round_choices: &[usize] = &[100, 300];
    let layers: Vec<_> = if cfg.quick {
        vec![resnet18::layer("conv1").unwrap(),
             resnet18::layer("conv5").unwrap()]
    } else {
        resnet18::LAYERS.to_vec()
    };
    let mut out = String::from(
        "== Fig 4: RMSE(A)/RMSE(P) vs #samples × boost rounds ==\n\n",
    );
    let mut header: Vec<String> = vec!["layer".into()];
    for &r in round_choices {
        for &s in sample_counts {
            header.push(format!("r{r}/n{s}"));
        }
    }
    let hdr: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut t = Table::new(&hdr);
    let mut per_round_avgs: Vec<Vec<f64>> =
        vec![Vec::new(); round_choices.len()];
    for layer in &layers {
        let records =
            data::space_profile(&cfg.hw, layer, limit, cfg.seed);
        let mut row = vec![layer.name.to_string()];
        for (ri, &rounds) in round_choices.iter().enumerate() {
            for &n in sample_counts {
                let mut ratios = Vec::new();
                for rep in 0..cfg.repeats {
                    if let Some((p, a)) = rmse_pair(
                        &records,
                        rounds,
                        n,
                        cfg.seed ^ (rep as u64) << 8,
                    ) {
                        if p > 0.0 {
                            ratios.push(a / p);
                        }
                    }
                }
                if ratios.is_empty() {
                    row.push("-".into());
                } else {
                    let m = mean(&ratios);
                    per_round_avgs[ri].push(m);
                    row.push(f(m, 3));
                }
            }
        }
        t.row(&row);
    }
    out.push_str(&t.render());
    for (ri, &rounds) in round_choices.iter().enumerate() {
        out.push_str(&format!(
            "avg ratio @ {rounds} rounds: {:.3}\n",
            mean(&per_round_avgs[ri])
        ));
    }
    out.push_str(
        "(paper: ratio < 1 for most layers; increasing rounds 100→300 \
         improves accuracy 0.916→0.932)\n",
    );
    out
}
