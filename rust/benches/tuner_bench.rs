//! Tuning-loop benchmarks: full trials/second per tuner — the end-to-end
//! rate every experiment (fig2a/fig5/headline) is built on.
use ml2tuner::tuner::ml2tuner::Ml2Tuner;
use ml2tuner::tuner::random_baseline::RandomTuner;
use ml2tuner::tuner::tvm_baseline::TvmTuner;
use ml2tuner::tuner::{Tuner, TunerConfig, TuningEnv};
use ml2tuner::util::bench::Bench;
use ml2tuner::vta::config::VtaConfig;
use ml2tuner::workloads::resnet18;

fn main() {
    let mut b = Bench::with_budget(3.0);
    for layer in ["conv1", "conv5"] {
        let env = TuningEnv::new(VtaConfig::zcu102(),
                                 resnet18::layer(layer).unwrap());
        let trials = 100usize;
        let mut seed = 0u64;
        let mut cfgs = move || {
            seed += 1;
            TunerConfig { max_trials: trials, seed, ..Default::default() }
        };
        b.run_items(&format!("ml2tuner {layer} ({trials} trials)"),
                    trials as f64,
                    || Ml2Tuner::new(cfgs()).tune(&env));
        b.run_items(&format!("tvm {layer} ({trials} trials)"),
                    trials as f64,
                    || TvmTuner::new(cfgs()).tune(&env));
        b.run_items(&format!("random {layer} ({trials} trials)"),
                    trials as f64,
                    || RandomTuner::new(cfgs()).tune(&env));
    }
    print!("{}", b.summary());
    b.maybe_write_json("tuner_bench");
}
