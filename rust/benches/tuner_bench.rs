//! Tuning-loop benchmarks: full trials/second per tuner — the end-to-end
//! rate every experiment (fig2a/fig5/headline) is built on — plus the
//! PR-5 scoring-sweep bench: decode+score a 400k-candidate extended
//! space through the legacy row-at-a-time path (frozen here as the
//! reference) vs the flattened batched sweep at `--jobs` 1 and 4. See
//! EXPERIMENTS.md §Performance methodology for how these rows feed
//! `BENCH_5.json` and the regression gate.
#[path = "../tests/common/legacy_sim.rs"]
mod legacy_sim;

use ml2tuner::compiler::schedule::SpaceKind;
use ml2tuner::compiler::Compiler;
use ml2tuner::obs::Recorder;
use ml2tuner::tuner::database::{Database, Fidelity, Outcome, TrialRecord};
use ml2tuner::tuner::explorer::score_candidates;
use ml2tuner::tuner::ml2tuner::Ml2Tuner;
use ml2tuner::tuner::models::{FitOpts, ModelP, ModelV};
use ml2tuner::tuner::random_baseline::RandomTuner;
use ml2tuner::tuner::space::SearchSpace;
use ml2tuner::tuner::train::{Provenance, TrainSet};
use ml2tuner::tuner::tvm_baseline::TvmTuner;
use ml2tuner::tuner::{Tuner, TunerConfig, TuningEnv};
use ml2tuner::util::bench::Bench;
use ml2tuner::vta::coarse::{self, CoarseEstimate};
use ml2tuner::vta::config::VtaConfig;
use ml2tuner::workloads::{self, resnet18};

/// The ISSUE-5 headline numbers: decode+score ≥400k extended-space
/// candidates. Models are trained on a synthetic labelling (no
/// profiling in the setup), then the same candidate list is scored by
/// (a) the frozen pre-flattening reference — one fresh `Vec<f64>` and
/// two pointer-chasing per-row walks per candidate, one core — and
/// (b) the batched flattened sweep at jobs=1 and jobs=4.
fn scoring_sweep(b: &mut Bench) {
    // vgg16/conv2_2 extended: 737,280 points — comfortably over the
    // 400k sweep this bench pins
    let layer = workloads::network("vgg16")
        .unwrap()
        .layer("conv2_2")
        .unwrap();
    let space = SearchSpace::with_kind(&layer, SpaceKind::Extended);
    assert!(space.len() >= 400_000, "bench layer shrank: {}", space.len());
    let mut db = Database::new("conv2_2");
    let stride = space.len() / 512;
    for k in 0..512usize {
        let i = k * stride;
        let s = space.schedule(i);
        let valid = s.tile_h * s.n_vthreads <= 28;
        let cycles = (1_000_000 / (s.tile_h * s.tile_w)
            + 5_000 * s.n_vthreads) as u64;
        db.push(TrialRecord {
            space_index: i,
            schedule: s,
            visible: space.visible(i),
            hidden: vec![],
            outcome: if valid {
                Outcome::Valid { cycles }
            } else {
                Outcome::Crash
            },
            fidelity: Fidelity::Full,
        });
    }
    let opts = FitOpts::new(60, 1);
    let mut pset = TrainSet::new();
    pset.extend_p(&db, Provenance::Cold);
    let mut vset = TrainSet::new();
    vset.extend_v(&db, Provenance::Cold);
    let p = ModelP::fit(&pset, &opts).unwrap();
    let v = ModelV::fit(&vset, &opts).unwrap();
    let idx: Vec<usize> = (0..400_000).collect();
    let n = idx.len() as f64;
    b.run_items("scoring-sweep legacy row-at-a-time", n, || {
        // frozen reference: what Explorer::select did before PR 5
        let mut acc = 0.0f64;
        for &i in &idx {
            let feats = space.visible(i);
            let tie = -v.margin(&feats);
            acc += p.predict(&feats) + tie;
        }
        acc
    });
    for jobs in [1usize, 4] {
        b.run_items(&format!("scoring-sweep flat jobs={jobs}"), n, || {
            score_candidates(&space, &p, Some(&v), &idx, jobs, None)
        });
    }
    // ISSUE-6 row: the same sweep with a live telemetry recorder
    // (span + per-chunk histogram + counters). The acceptance gate
    // wants this within 2% of the recorder-free row.
    let rec = Recorder::new();
    b.run_items("scoring-sweep flat jobs=4 +telemetry", n, || {
        score_candidates(&space, &p, Some(&v), &idx, 4, Some(&rec))
    });
}

/// The ISSUE-8 multi-fidelity rows: per-candidate cost of the tier-0
/// coarse analytic estimate vs full compile + three-timeline timing on
/// the same ≥400k extended sweep shape. The coarse row walks the whole
/// 400k-candidate list (decode + static check + cycle formulas, no
/// program build); the tier-1 reference compiles and simulates a
/// strided 1,024-candidate subsample — compiling 400k configs per
/// iteration would take hours, and the gate compares *per-candidate*
/// medians anyway (target ≥20x, read off BENCH_8.json).
fn coarse_vs_timing(b: &mut Bench) {
    let layer = workloads::network("vgg16")
        .unwrap()
        .layer("conv2_2")
        .unwrap();
    let env = TuningEnv::with_space(
        VtaConfig::zcu102(),
        layer,
        SpaceKind::Extended,
    );
    assert!(
        env.space.len() >= 400_000,
        "bench layer shrank: {}",
        env.space.len()
    );
    let idx: Vec<usize> = (0..400_000).collect();
    b.run_items("coarse-estimate batch (tier 0)", idx.len() as f64, || {
        let mut acc = 0u64;
        for &i in &idx {
            let sched = env.space.schedule(i);
            if let CoarseEstimate::Cycles(c) =
                coarse::estimate(env.hw(), &env.layer, &sched)
            {
                acc = acc.wrapping_add(c);
            }
        }
        acc
    });
    let stride = env.space.len() / 1_024;
    let sample: Vec<usize> = (0..1_024).map(|k| k * stride).collect();
    b.run_items(
        "full compile+timing (tier 1, sampled)",
        sample.len() as f64,
        || {
            let mut acc = 0u64;
            for &i in &sample {
                if let Outcome::Valid { cycles } = env.profile(i).outcome {
                    acc = acc.wrapping_add(cycles);
                }
            }
            acc
        },
    );
}

/// The ISSUE-9 incremental-training rows: per-round P-model train cost
/// at round-5/10/20 record counts (50/100/200 rows at the default 10
/// trials per round), full 120-round refit vs warm continuation — the
/// per-round plan appends `(boost_rounds/10).max(4) = 12` trees onto
/// the previous round's booster instead of regrowing all 120. The
/// acceptance gate reads the round-20 ratio off BENCH_9.json
/// (target >=3x).
fn continuation_vs_refit(b: &mut Bench) {
    let layer = resnet18::layer("conv5").unwrap();
    let space = SearchSpace::new(&layer);
    let synth = |rows: usize| {
        let stride = space.len() / rows;
        let mut db = Database::new("conv5");
        for k in 0..rows {
            let i = k * stride;
            let s = space.schedule(i);
            let cycles = (1_000_000 / (s.tile_h * s.tile_w)
                + 5_000 * s.n_vthreads) as u64;
            db.push(TrialRecord {
                space_index: i,
                schedule: s,
                visible: space.visible(i),
                hidden: vec![],
                outcome: Outcome::Valid { cycles },
                fidelity: Fidelity::Full,
            });
        }
        let mut set = TrainSet::new();
        set.extend_p(&db, Provenance::Cold);
        set
    };
    for round in [5usize, 10, 20] {
        let rows = round * 10;
        // last round's model: a full fit on everything but the newest
        // batch — what ModelState carries into this round
        let prev = synth(rows - 10);
        let base = ModelP::fit(&prev, &FitOpts::new(120, 7)).unwrap();
        let set = synth(rows);
        b.run(&format!("train P full refit (round {round}, {rows} rows)"),
              || ModelP::fit(&set, &FitOpts::new(120, 7)));
        b.run(
            &format!("train P continuation (round {round}, {rows} rows)"),
            || ModelP::fit(&set,
                           &FitOpts::new(12, 7).with_base(&base.booster)),
        );
    }
}

/// The ISSUE-10 rows: per-trial full-fidelity check on a fixed compiled
/// batch — the frozen pre-rewrite implementation vs the scratch-arena
/// hot path, each sharded over the worker pool at `--jobs` 1 and 4 the
/// way `Engine::profile_batch` shards trials (legacy gets plain
/// `par_map`, scratch gets one [`SimScratch`] per worker via
/// `par_map_with`). `scripts/bench_report.py --filter 'per-trial
/// check'` folds these into BENCH_10.json (gate: scratch ≥2x faster at
/// both worker counts).
fn per_trial_check(b: &mut Bench) {
    use ml2tuner::util::par::{par_map, par_map_with};
    use ml2tuner::util::rng::Rng;
    use ml2tuner::vta::{SimScratch, Simulator};

    let cfg = VtaConfig::zcu102();
    let compiler = Compiler::new(cfg.clone());
    let sim = Simulator::new(cfg.clone());
    let layer = resnet18::layer("conv5").unwrap();
    let space = SearchSpace::with_kind(&layer, SpaceKind::Extended);
    let mut rng = Rng::new(0xC0DE5);
    let progs: Vec<_> = (0..128)
        .map(|_| {
            let s = space.schedule(rng.below(space.len()));
            compiler.compile(&layer, &s).program
        })
        .collect();
    let n = progs.len() as f64;
    for jobs in [1usize, 4] {
        b.run_items(
            &format!("per-trial check legacy jobs={jobs}"),
            n,
            || {
                par_map(jobs, progs.len(), |k| {
                    legacy_sim::legacy_check(&cfg, &progs[k]).is_valid()
                })
            },
        );
        b.run_items(
            &format!("per-trial check scratch jobs={jobs}"),
            n,
            || {
                par_map_with(jobs, progs.len(), SimScratch::new, |s, k| {
                    sim.check_with(&progs[k], s).is_valid()
                })
            },
        );
    }
}

/// Median-over-median speedups of the sweep rows (the ratios the PR-5
/// acceptance gate reads off BENCH_5.json).
fn print_sweep_speedups(b: &Bench) {
    let median = |name: &str| {
        b.results
            .iter()
            .find(|r| r.name == name)
            .map(|r| r.median.as_secs_f64())
    };
    let Some(legacy) = median("scoring-sweep legacy row-at-a-time") else {
        return;
    };
    for jobs in [1usize, 4] {
        if let Some(flat) = median(&format!("scoring-sweep flat jobs={jobs}"))
        {
            println!(
                "scoring-sweep speedup vs legacy at jobs={jobs}: {:.2}x",
                legacy / flat
            );
        }
    }
    // telemetry overhead: recorder-on vs recorder-off at jobs=4
    // (ISSUE-6 gate: < 2%)
    if let (Some(off), Some(on)) = (
        median("scoring-sweep flat jobs=4"),
        median("scoring-sweep flat jobs=4 +telemetry"),
    ) {
        println!(
            "telemetry overhead at jobs=4: {:+.2}% (off {:.3}s, on {:.3}s)",
            (on / off - 1.0) * 100.0,
            off,
            on
        );
    }
    // ISSUE-8 gate: per-candidate tier-0 vs tier-1 cost (target ≥20x)
    let per_item = |name: &str| {
        b.results.iter().find(|r| r.name == name).map(|r| {
            r.median.as_secs_f64() / r.items_per_iter.unwrap_or(1.0)
        })
    };
    if let (Some(coarse), Some(full)) = (
        per_item("coarse-estimate batch (tier 0)"),
        per_item("full compile+timing (tier 1, sampled)"),
    ) {
        println!(
            "tier-0 coarse estimate vs tier-1 compile+timing: {:.1}x \
             cheaper per candidate (coarse {:.0} ns, full {:.0} ns; \
             target >=20x)",
            full / coarse,
            coarse * 1e9,
            full * 1e9
        );
    }
    // ISSUE-9 gate: warm continuation vs full refit per round
    // (target >=3x at round 20)
    for round in [5usize, 10, 20] {
        let rows = round * 10;
        if let (Some(full), Some(cont)) = (
            median(&format!(
                "train P full refit (round {round}, {rows} rows)"
            )),
            median(&format!(
                "train P continuation (round {round}, {rows} rows)"
            )),
        ) {
            println!(
                "per-round train, continuation vs full refit at round \
                 {round} ({rows} rows): {:.2}x faster{}",
                full / cont,
                if round == 20 { " (target >=3x)" } else { "" }
            );
        }
    }
    // ISSUE-10 gate: scratch-arena check vs frozen legacy per trial
    // (target >=2x at both worker counts)
    for jobs in [1usize, 4] {
        if let (Some(old), Some(new)) = (
            median(&format!("per-trial check legacy jobs={jobs}")),
            median(&format!("per-trial check scratch jobs={jobs}")),
        ) {
            println!(
                "per-trial check, scratch vs frozen legacy at \
                 jobs={jobs}: {:.2}x faster (target >=2x)",
                old / new
            );
        }
    }
}

fn main() {
    let mut b = Bench::with_budget(3.0);
    for layer in ["conv1", "conv5"] {
        let env = TuningEnv::new(VtaConfig::zcu102(),
                                 resnet18::layer(layer).unwrap());
        let trials = 100usize;
        let mut seed = 0u64;
        let mut cfgs = move || {
            seed += 1;
            TunerConfig { max_trials: trials, seed, ..Default::default() }
        };
        b.run_items(&format!("ml2tuner {layer} ({trials} trials)"),
                    trials as f64,
                    || Ml2Tuner::new(cfgs()).tune(&env));
        b.run_items(&format!("tvm {layer} ({trials} trials)"),
                    trials as f64,
                    || TvmTuner::new(cfgs()).tune(&env));
        b.run_items(&format!("random {layer} ({trials} trials)"),
                    trials as f64,
                    || RandomTuner::new(cfgs()).tune(&env));
    }
    scoring_sweep(&mut b);
    coarse_vs_timing(&mut b);
    continuation_vs_refit(&mut b);
    per_trial_check(&mut b);
    print!("{}", b.summary());
    print_sweep_speedups(&b);
    b.maybe_write_json("tuner_bench");
}
