//! PJRT runtime benchmarks: artifact compile (once, cached) and golden
//! conv execution latency (the per-profile validity check on the real
//! system). Requires `make artifacts`.
use ml2tuner::runtime::Runtime;
use ml2tuner::util::bench::Bench;
use ml2tuner::workloads::{resnet18, synth};

fn main() {
    let mut rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping runtime bench (run `make artifacts`): {e}");
            return;
        }
    };
    let mut b = Bench::with_budget(2.0);
    for name in ["conv1", "conv4", "conv5"] {
        let layer = resnet18::layer(name).unwrap();
        let x: Vec<i32> = synth::input_data(&layer, 1)
            .iter().map(|&v| v as i32).collect();
        let w: Vec<i32> = synth::weight_data(&layer, 1)
            .iter().map(|&v| v as i32).collect();
        // first call compiles (cache miss) — measure separately
        let t0 = std::time::Instant::now();
        rt.execute_conv(&layer, &x, &w).unwrap();
        println!("{name}: first-call (compile+run) {:?}", t0.elapsed());
        b.run(&format!("golden conv {name} (cached exe)"), || {
            rt.execute_conv(&layer, &x, &w).unwrap()
        });
    }
    print!("{}", b.summary());
    b.maybe_write_json("runtime_bench");
}
