//! Engine benchmarks: batched profiling throughput vs worker count
//! (cold compile cache — measures the compile+simulate hot path actually
//! scaling with cores) and the compile-cache hit speedup (warm cache —
//! what the ML²Tuner A-stage pays when profiling its re-ranked pool).
use ml2tuner::engine::Engine;
use ml2tuner::tuner::TuningEnv;
use ml2tuner::util::bench::Bench;
use ml2tuner::vta::config::VtaConfig;
use ml2tuner::workloads::resnet18;

fn main() {
    let mut b = Bench::with_budget(2.0);
    let env = TuningEnv::new(VtaConfig::zcu102(),
                             resnet18::layer("conv5").unwrap());
    // a spread of 64 schedules across the space (one pool's worth of
    // A-stage compiles is ~20; 64 gives the pool workers something to
    // chew on without the batch being trivially short)
    let stride = (env.space.len() / 64).max(1);
    let batch: Vec<usize> =
        (0..env.space.len()).step_by(stride).take(64).collect();

    for jobs in [1usize, 2, 4] {
        b.run_items(
            &format!("profile_batch {} cfgs, cold cache, jobs={jobs}",
                     batch.len()),
            batch.len() as f64,
            || {
                // fresh engine per iteration: every compile is a miss
                Engine::with_jobs(jobs).profile_batch(&env, &batch)
            },
        );
    }

    // warm cache: the batch was already compiled (A-stage reuse), so
    // profiling is check()-only — the speedup vs cold/jobs=1 is what the
    // cache saves per round
    let warm = Engine::with_jobs(1);
    warm.profile_batch(&env, &batch);
    b.run_items(
        &format!("profile_batch {} cfgs, warm cache, jobs=1", batch.len()),
        batch.len() as f64,
        || warm.profile_batch(&env, &batch),
    );
    let stats = warm.cache().stats();
    println!(
        "warm-cache stats: {} hits / {} lookups ({:.1}% hit rate, {} \
         compiles total)",
        stats.hits,
        stats.lookups(),
        stats.hit_rate() * 100.0,
        stats.misses
    );
    print!("{}", b.summary());
    b.maybe_write_json("engine_bench");
}
