//! GBDT substrate benchmarks: training + batched prediction throughput
//! (the explorer scores the entire space every tuning round — predict
//! throughput is the L3 hot path, see EXPERIMENTS.md §Perf).
use ml2tuner::gbdt::{
    Booster, Dataset, FeatureMatrix, GbdtParams, Objective, TrainOpts,
};
use ml2tuner::util::bench::Bench;
use ml2tuner::util::rng::Rng;

fn synth(n: usize, nf: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
    let mut r = Rng::new(seed);
    let rows: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..nf).map(|_| r.range_f64(0.0, 10.0)).collect())
        .collect();
    let labels: Vec<f64> = rows
        .iter()
        .map(|x| x[0] * x[0] + 3.0 * x[1] - x[2] * x[3])
        .collect();
    (rows, labels)
}

fn main() {
    let mut b = Bench::with_budget(2.0);
    let (rows, labels) = synth(300, 11, 1);
    let d = Dataset::from_rows(&rows, &labels);

    // in-loop retrain cost (ModelP during tuning: 120 rounds, depth 14)
    let p_loop = GbdtParams::model_p().with_rounds(120);
    b.run("train P (300 rows, 120 rounds)", || {
        Booster::fit(&p_loop, &d, &TrainOpts::default())
    });
    let v = GbdtParams::model_v().with_rounds(120);
    b.run("train V (300 rows, 120 rounds)", || {
        Booster::fit(&v, &d, &TrainOpts::default())
    });
    let rank = GbdtParams::model_p()
        .with_rounds(60)
        .with_objective(Objective::RankPairwise);
    b.run("train rank:pairwise (300 rows, 60 rounds)", || {
        Booster::fit(&rank, &d, &TrainOpts::default())
    });

    // batched predict: the explorer scores ~20k configs per round
    let model = Booster::fit(&p_loop, &d, &TrainOpts::default());
    let (space, _) = synth(20_000, 11, 2);
    b.run_items("predict 20k rows (Vec<f64> path)", 20_000.0, || {
        let mut acc = 0.0;
        for row in &space {
            acc += model.predict_row(row);
        }
        acc
    });
    let space_f32: Vec<Vec<f32>> = space
        .iter()
        .map(|r| r.iter().map(|&v| v as f32).collect())
        .collect();
    b.run_items("predict 20k rows (f32 fast path)", 20_000.0, || {
        let mut acc = 0.0;
        for row in &space_f32 {
            acc += model.predict_row_f32(row);
        }
        acc
    });
    // flattened SoA batch kernel (PR 5): trees-outer/rows-inner over a
    // row-major matrix, bit-identical outputs
    let flat = model.flatten();
    let matrix = FeatureMatrix::from_rows(&space);
    let mut out: Vec<f64> = Vec::new();
    b.run_items("predict 20k rows (flat batch)", 20_000.0, || {
        flat.predict_batch_into(&matrix, &mut out);
        out.last().copied()
    });
    print!("{}", b.summary());
    b.maybe_write_json("gbdt_bench");
}
