//! VTA simulator benchmarks: compile + check() is the profiling fast path
//! (one per tuning trial); numeric execution is the validation slow path.
//! The `per-trial check` rows compare the frozen pre-rewrite check
//! (tests/common/legacy_sim.rs) against the scratch-arena hot path on
//! one thread; `scripts/bench_report.py --filter 'per-trial check'`
//! folds them into BENCH_10.json (gate: scratch ≥2x faster).

#[path = "../tests/common/legacy_sim.rs"]
mod legacy_sim;

use ml2tuner::compiler::schedule::{space_for, SpaceKind};
use ml2tuner::compiler::{schedule::Schedule, Compiler};
use ml2tuner::util::bench::Bench;
use ml2tuner::util::rng::Rng;
use ml2tuner::vta::isa::Program;
use ml2tuner::vta::{config::VtaConfig, functional, layout, SimScratch,
                    Simulator};
use ml2tuner::workloads::{resnet18, synth};

/// Deterministic mixed corpus (valid + faulty) of compiled extended-space
/// programs — the per-trial unit the tuning loop pays for every profile.
fn check_corpus(compiler: &Compiler, n: usize) -> Vec<Program> {
    let layer = resnet18::layer("conv5").unwrap();
    let space = space_for(&layer, SpaceKind::Extended);
    let mut rng = Rng::new(0xC0DE5);
    (0..n)
        .map(|_| {
            let s = space.schedule(rng.below(space.len()));
            compiler.compile(&layer, &s).program
        })
        .collect()
}

fn per_trial_check(b: &mut Bench, cfg: &VtaConfig, compiler: &Compiler) {
    let sim = Simulator::new(cfg.clone());
    let progs = check_corpus(compiler, 64);
    let n = progs.len() as f64;
    b.run_items("per-trial check legacy (frozen, 1 thread)", n, || {
        let mut valid = 0usize;
        for p in &progs {
            valid += legacy_sim::legacy_check(cfg, p).is_valid() as usize;
        }
        valid
    });
    let mut scratch = SimScratch::new();
    b.run_items("per-trial check scratch (warmed, 1 thread)", n, || {
        let mut valid = 0usize;
        for p in &progs {
            valid += sim.check_with(p, &mut scratch).is_valid() as usize;
        }
        valid
    });
    let median = |name: &str| {
        b.results
            .iter()
            .find(|r| r.name == name)
            .map(|r| r.median.as_secs_f64())
    };
    if let (Some(old), Some(new)) = (
        median("per-trial check legacy (frozen, 1 thread)"),
        median("per-trial check scratch (warmed, 1 thread)"),
    ) {
        println!(
            "per-trial check speedup vs frozen legacy: {:.2}x \
             (target >=2x)",
            old / new
        );
    }
}

fn main() {
    let cfg = VtaConfig::zcu102();
    let compiler = Compiler::new(cfg.clone());
    let sim = Simulator::new(cfg.clone());
    let mut b = Bench::with_budget(2.0);

    for (name, sched) in [
        ("conv1 8x8", Schedule { tile_h: 8, tile_w: 8, tile_oc: 64,
                                 tile_ic: 64, n_vthreads: 2,
                                 ..Default::default() }),
        ("conv1 2x2 (many instrs)", Schedule { tile_h: 2, tile_w: 2,
            tile_oc: 16, tile_ic: 16, n_vthreads: 1,
            ..Default::default() }),
        ("conv5 7x7", Schedule { tile_h: 7, tile_w: 7, tile_oc: 64,
                                 tile_ic: 64, n_vthreads: 1,
                                 ..Default::default() }),
    ] {
        let layer = if name.starts_with("conv1") {
            resnet18::layer("conv1").unwrap()
        } else {
            resnet18::layer("conv5").unwrap()
        };
        b.run(&format!("compile {name}"), || {
            compiler.compile(&layer, &sched)
        });
        let compiled = compiler.compile(&layer, &sched);
        b.run(&format!("check {name} ({} instrs)",
                       compiled.program.len()),
              || sim.check(&compiled.program));
    }

    // full numeric execution (validation path)
    let layer = resnet18::layer("conv5").unwrap();
    let sched = Schedule { tile_h: 7, tile_w: 7, tile_oc: 64,
                           tile_ic: 64, n_vthreads: 1,
                           ..Default::default() };
    let compiled = compiler.compile(&layer, &sched);
    let x = synth::input_data(&layer, 1);
    let w = synth::weight_data(&layer, 1);
    let dram = functional::Dram {
        inp: layout::pack_input(&cfg, &x, layer.h, layer.w, layer.c),
        wgt: layout::pack_weights(&cfg, &w, layer.kh, layer.kw, layer.c,
                                  layer.kc),
        out_vecs: compiled.program.dram_out_vecs,
    };
    b.run("numeric execute conv5 (25M MACs)", || {
        sim.execute(&compiled.program, &dram).unwrap()
    });
    per_trial_check(&mut b, &cfg, &compiler);
    print!("{}", b.summary());
    b.maybe_write_json("vta_sim_bench");
}
