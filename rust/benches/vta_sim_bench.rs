//! VTA simulator benchmarks: compile + check() is the profiling fast path
//! (one per tuning trial); numeric execution is the validation slow path.
use ml2tuner::compiler::{schedule::Schedule, Compiler};
use ml2tuner::util::bench::Bench;
use ml2tuner::vta::{config::VtaConfig, functional, layout, Simulator};
use ml2tuner::workloads::{resnet18, synth};

fn main() {
    let cfg = VtaConfig::zcu102();
    let compiler = Compiler::new(cfg.clone());
    let sim = Simulator::new(cfg.clone());
    let mut b = Bench::with_budget(2.0);

    for (name, sched) in [
        ("conv1 8x8", Schedule { tile_h: 8, tile_w: 8, tile_oc: 64,
                                 tile_ic: 64, n_vthreads: 2,
                                 ..Default::default() }),
        ("conv1 2x2 (many instrs)", Schedule { tile_h: 2, tile_w: 2,
            tile_oc: 16, tile_ic: 16, n_vthreads: 1,
            ..Default::default() }),
        ("conv5 7x7", Schedule { tile_h: 7, tile_w: 7, tile_oc: 64,
                                 tile_ic: 64, n_vthreads: 1,
                                 ..Default::default() }),
    ] {
        let layer = if name.starts_with("conv1") {
            resnet18::layer("conv1").unwrap()
        } else {
            resnet18::layer("conv5").unwrap()
        };
        b.run(&format!("compile {name}"), || {
            compiler.compile(&layer, &sched)
        });
        let compiled = compiler.compile(&layer, &sched);
        b.run(&format!("check {name} ({} instrs)",
                       compiled.program.len()),
              || sim.check(&compiled.program));
    }

    // full numeric execution (validation path)
    let layer = resnet18::layer("conv5").unwrap();
    let sched = Schedule { tile_h: 7, tile_w: 7, tile_oc: 64,
                           tile_ic: 64, n_vthreads: 1,
                           ..Default::default() };
    let compiled = compiler.compile(&layer, &sched);
    let x = synth::input_data(&layer, 1);
    let w = synth::weight_data(&layer, 1);
    let dram = functional::Dram {
        inp: layout::pack_input(&cfg, &x, layer.h, layer.w, layer.c),
        wgt: layout::pack_weights(&cfg, &w, layer.kh, layer.kw, layer.c,
                                  layer.kc),
        out_vecs: compiled.program.dram_out_vecs,
    };
    b.run("numeric execute conv5 (25M MACs)", || {
        sim.execute(&compiled.program, &dram).unwrap()
    });
    print!("{}", b.summary());
    b.maybe_write_json("vta_sim_bench");
}
