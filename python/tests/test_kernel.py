"""L1 kernel correctness: Pallas VTA GEMM/conv vs the pure-jnp oracle.

Integer semantics mean *bit-exact* equality, not allclose. Hypothesis sweeps
shapes, strides, pads, shifts and block sizes.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, vta_conv
from compile import model

RNG = np.random.default_rng(1234)


def rand_i8(shape):
    return RNG.integers(-128, 128, shape, dtype=np.int8)


def assert_bitexact(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------- gemm ----


class TestGemmQ:
    @pytest.mark.parametrize("m,k,n", [(1, 1, 1), (7, 5, 3), (128, 64, 16),
                                       (130, 576, 64), (256, 128, 256)])
    def test_matches_oracle(self, m, k, n):
        x, w = rand_i8((m, k)), rand_i8((k, n))
        got = vta_conv.gemm_q(jnp.asarray(x), jnp.asarray(w), shift=8)
        want = ref.gemm_ref(jnp.asarray(x), jnp.asarray(w), shift=8)
        assert_bitexact(got, want)

    @pytest.mark.parametrize("shift", [0, 1, 4, 8, 15, 31])
    def test_shift_sweep(self, shift):
        x, w = rand_i8((33, 48)), rand_i8((48, 17))
        got = vta_conv.gemm_q(jnp.asarray(x), jnp.asarray(w), shift=shift)
        want = ref.gemm_ref(jnp.asarray(x), jnp.asarray(w), shift=shift)
        assert_bitexact(got, want)

    @pytest.mark.parametrize("bm,bn", [(16, 16), (32, 128), (128, 32),
                                       (256, 256)])
    def test_block_shape_invariance(self, bm, bn):
        """Tiling must never change integer results (the property that makes
        output-mismatch a genuine invalidity signal on VTA)."""
        x, w = rand_i8((100, 72)), rand_i8((72, 40))
        got = vta_conv.gemm_q(jnp.asarray(x), jnp.asarray(w), shift=8,
                              bm=bm, bn=bn)
        want = ref.gemm_ref(jnp.asarray(x), jnp.asarray(w), shift=8)
        assert_bitexact(got, want)

    def test_saturation_clips_to_int8(self):
        x = np.full((8, 64), 127, dtype=np.int8)
        w = np.full((64, 8), 127, dtype=np.int8)
        got = np.asarray(vta_conv.gemm_q(jnp.asarray(x), jnp.asarray(w),
                                         shift=0))
        assert (got == 127).all()
        w_neg = np.full((64, 8), -128, dtype=np.int8)
        got = np.asarray(vta_conv.gemm_q(jnp.asarray(x), jnp.asarray(w_neg),
                                         shift=0))
        assert (got == -128).all()

    def test_negative_shift_floor_semantics(self):
        """Arithmetic >> floors toward -inf: (-1 >> 8) == -1, not 0."""
        x = np.full((1, 1), -1, dtype=np.int8)
        w = np.full((1, 1), 1, dtype=np.int8)
        got = np.asarray(vta_conv.gemm_q(jnp.asarray(x), jnp.asarray(w),
                                         shift=8))
        assert got[0, 0] == -1

    @settings(max_examples=40, deadline=None)
    @given(
        m=st.integers(1, 96), k=st.integers(1, 96), n=st.integers(1, 96),
        shift=st.integers(0, 16), seed=st.integers(0, 2**31),
    )
    def test_hypothesis_gemm(self, m, k, n, shift, seed):
        r = np.random.default_rng(seed)
        x = r.integers(-128, 128, (m, k), dtype=np.int8)
        w = r.integers(-128, 128, (k, n), dtype=np.int8)
        got = vta_conv.gemm_q(jnp.asarray(x), jnp.asarray(w), shift=shift)
        want = ref.gemm_ref(jnp.asarray(x), jnp.asarray(w), shift=shift)
        assert_bitexact(got, want)


# ---------------------------------------------------------------- conv ----


class TestConv2dQ:
    @pytest.mark.parametrize("layer", model.RESNET18_LAYERS,
                             ids=lambda l: l.name)
    def test_resnet18_layers_match_oracle(self, layer):
        x = rand_i8((layer.h, layer.w, layer.c))
        w = rand_i8((layer.kh, layer.kw, layer.c, layer.kc))
        got = vta_conv.conv2d_q(jnp.asarray(x), jnp.asarray(w),
                                pad=layer.pad, stride=layer.stride,
                                shift=model.SHIFT)
        want = ref.conv2d_ref(jnp.asarray(x), jnp.asarray(w),
                              pad=layer.pad, stride=layer.stride,
                              shift=model.SHIFT)
        assert got.shape == (layer.oh, layer.ow, layer.kc)
        assert_bitexact(got, want)

    @settings(max_examples=25, deadline=None)
    @given(
        h=st.integers(3, 20), w=st.integers(3, 20),
        c=st.integers(1, 16), kc=st.integers(1, 24),
        ksz=st.sampled_from([1, 3, 5]),
        pad=st.integers(0, 2), stride=st.sampled_from([1, 2]),
        shift=st.integers(0, 12), seed=st.integers(0, 2**31),
    )
    def test_hypothesis_conv(self, h, w, c, kc, ksz, pad, stride, shift,
                             seed):
        if h + 2 * pad < ksz or w + 2 * pad < ksz:
            return  # degenerate: kernel larger than padded input
        r = np.random.default_rng(seed)
        x = r.integers(-128, 128, (h, w, c), dtype=np.int8)
        wt = r.integers(-128, 128, (ksz, ksz, c, kc), dtype=np.int8)
        got = vta_conv.conv2d_q(jnp.asarray(x), jnp.asarray(wt),
                                pad=pad, stride=stride, shift=shift)
        want = ref.conv2d_ref(jnp.asarray(x), jnp.asarray(wt),
                              pad=pad, stride=stride, shift=shift)
        assert_bitexact(got, want)


# -------------------------------------------------------------- im2col ----


class TestIm2col:
    def test_identity_1x1(self):
        x = rand_i8((5, 7, 3))
        patches, (oh, ow) = vta_conv.im2col(jnp.asarray(x), kh=1, kw=1,
                                            pad=0, stride=1)
        assert (oh, ow) == (5, 7)
        assert_bitexact(patches, x.reshape(35, 3))

    def test_k_ordering_is_khkwc(self):
        """K axis must be ordered (kh, kw, c) -- the weight reshape and the
        rust simulator's LOAD staging both assume it."""
        x = np.arange(16, dtype=np.int8).reshape(4, 4, 1)
        patches, _ = vta_conv.im2col(jnp.asarray(x), kh=3, kw=3, pad=1,
                                     stride=1)
        # centre pixel (1,1): rows of the 3x3 neighbourhood in scan order
        got = np.asarray(patches)[1 * 4 + 1]
        want = np.array([0, 1, 2, 4, 5, 6, 8, 9, 10], dtype=np.int8)
        assert_bitexact(got, want)

    def test_stride_and_pad_shapes(self):
        x = rand_i8((9, 9, 2))
        patches, (oh, ow) = vta_conv.im2col(jnp.asarray(x), kh=3, kw=3,
                                            pad=1, stride=2)
        assert (oh, ow) == (5, 5)
        assert patches.shape == (25, 18)
