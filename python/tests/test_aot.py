"""AOT export tests: HLO text round-trip, manifest integrity."""

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.export_all(str(out))
    return out, manifest


class TestExport:
    def test_manifest_covers_all_layers(self, exported):
        _, manifest = exported
        assert set(manifest["layers"]) == {f"conv{i}" for i in range(1, 11)}
        assert len(manifest["artifacts"]) == 5  # deduped shapes

    def test_artifacts_exist_and_parse_as_hlo(self, exported):
        out, manifest = exported
        for fname in manifest["artifacts"]:
            text = open(os.path.join(out, fname)).read()
            assert text.startswith("HloModule"), fname
            # i32 interface (rust literal limitation) and int8 internals
            assert "s32[" in text and "s8[" in text, fname

    def test_entry_shapes_match_layer(self, exported):
        out, manifest = exported
        info = manifest["layers"]["conv1"]
        text = open(os.path.join(out, info["artifact"])).read()
        assert f"s32[{info['h']},{info['w']},{info['c']}]" in text
        assert (
            f"s32[{info['kh']},{info['kw']},{info['c']},{info['kc']}]" in text
        )

    def test_manifest_json_round_trip(self, exported):
        out, manifest = exported
        loaded = json.load(open(os.path.join(out, "manifest.json")))
        assert loaded == json.loads(json.dumps(manifest))
        assert loaded["shift"] == model.SHIFT

    def test_dedup_targets_shared_artifact(self, exported):
        _, manifest = exported
        layers = manifest["layers"]
        assert layers["conv6"]["artifact"] == layers["conv2"]["artifact"]
        assert layers["conv9"]["artifact"] == layers["conv3"]["artifact"]
        assert layers["conv10"]["artifact"] == layers["conv4"]["artifact"]
