"""L2 model graph tests: i32 boundary, layer table integrity, lowering."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref


class TestLayerTable:
    def test_ten_layers(self):
        assert len(model.RESNET18_LAYERS) == 10
        assert [l.name for l in model.RESNET18_LAYERS] == [
            f"conv{i}" for i in range(1, 11)
        ]

    @pytest.mark.parametrize("layer", model.RESNET18_LAYERS,
                             ids=lambda l: l.name)
    def test_output_shape_consistent(self, layer):
        """Table 2a's OH/OW columns must match the conv arithmetic."""
        oh = (layer.h + 2 * layer.pad - layer.kh) // layer.stride + 1
        ow = (layer.w + 2 * layer.pad - layer.kw) // layer.stride + 1
        assert (oh, ow) == (layer.oh, layer.ow), layer.name

    def test_shape_dedup_groups(self):
        """Paper repeats shapes: conv6==conv2, conv7==conv9==conv3,
        conv8==conv10==conv4."""
        key = {l.name: l.shape_key() for l in model.RESNET18_LAYERS}
        assert key["conv6"] == key["conv2"]
        assert key["conv7"] == key["conv3"] == key["conv9"]
        assert key["conv8"] == key["conv4"] == key["conv10"]
        assert len(set(key.values())) == 5

    def test_gemm_dims(self):
        c1 = model.layer_by_name("conv1")
        assert (c1.m, c1.k, c1.n) == (3136, 576, 64)


class TestConvFn:
    @pytest.mark.parametrize("name", ["conv1", "conv5"])
    def test_i32_boundary_matches_oracle(self, name):
        layer = model.layer_by_name(name)
        r = np.random.default_rng(7)
        x8 = r.integers(-128, 128, (layer.h, layer.w, layer.c), dtype=np.int8)
        w8 = r.integers(-128, 128,
                        (layer.kh, layer.kw, layer.c, layer.kc),
                        dtype=np.int8)
        fn = model.conv_fn(layer)
        (y_i32,) = fn(jnp.asarray(x8, jnp.int32), jnp.asarray(w8, jnp.int32))
        assert y_i32.dtype == jnp.int32
        want = ref.conv2d_ref(jnp.asarray(x8), jnp.asarray(w8),
                              pad=layer.pad, stride=layer.stride,
                              shift=model.SHIFT)
        np.testing.assert_array_equal(np.asarray(y_i32, np.int8),
                                      np.asarray(want))

    def test_lowering_all_unique_shapes(self):
        seen = set()
        for layer in model.RESNET18_LAYERS:
            if layer.shape_key() in seen:
                continue
            seen.add(layer.shape_key())
            low = model.lowered(layer.name)
            mod = low.compiler_ir("stablehlo")
            assert "func" in str(mod)
