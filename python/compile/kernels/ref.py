"""Pure-jnp correctness oracle for the VTA-semantics quantized conv kernel.

This is the *reference* implementation the Pallas kernel (vta_conv.py) is
tested against at build time, and the semantics the rust VTA functional
simulator must match bit-exactly for valid configurations.

Extended-VTA GEMM-core semantics (paper Table 1: LOG_INP_WIDTH=3,
LOG_WGT_WIDTH=3, LOG_ACC_WIDTH=5):

  * inputs  : signed 8-bit
  * weights : signed 8-bit
  * accum   : signed 32-bit, exact integer accumulation
  * output  : arithmetic right shift by `shift`, clipped to [-128, 127],
              stored back as signed 8-bit

All arithmetic is integer-exact, so any correct tiling produces bit-identical
outputs -- which is what makes "output differs from expected" a meaningful
validity signal in the paper's profiling step.
"""

import jax
import jax.numpy as jnp


def requantize(acc_i32: jax.Array, shift: int) -> jax.Array:
    """VTA ALU store path: arithmetic shift right then clip to int8."""
    shifted = jax.lax.shift_right_arithmetic(acc_i32, jnp.int32(shift))
    return jnp.clip(shifted, -128, 127).astype(jnp.int8)


def conv2d_ref(
    x_i8: jax.Array,  # (H, W, C) int8
    w_i8: jax.Array,  # (KH, KW, C, KC) int8
    *,
    pad: int,
    stride: int,
    shift: int,
) -> jax.Array:  # (OH, OW, KC) int8
    """Quantized conv2d via XLA's convolution, int32 accumulation."""
    lhs = x_i8.astype(jnp.int32)[None]  # NHWC
    rhs = w_i8.astype(jnp.int32)  # HWIO
    acc = jax.lax.conv_general_dilated(
        lhs,
        rhs,
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32,
    )[0]
    return requantize(acc, shift)


def gemm_ref(x_i8: jax.Array, w_i8: jax.Array, *, shift: int) -> jax.Array:
    """Quantized (M,K)x(K,N) GEMM oracle with the same requantize path."""
    acc = jnp.dot(
        x_i8.astype(jnp.int32),
        w_i8.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    return requantize(acc, shift)
