"""L1 Pallas kernel: extended-VTA GEMM core as a blocked Pallas matmul.

The profiling hot-spot of the ML2Tuner reproduction is executing a conv layer
with extended-VTA semantics (int8 x int8 -> int32 accumulate -> shift + clip
-> int8). We express it as an im2col GEMM whose inner blocked matmul is a
Pallas kernel.

Hardware-adaptation notes (how VTA's scratchpad schedule maps to Pallas):

  * VTA stages (block=16)-sized input/weight tiles in its INP/WGT scratchpads
    and accumulates in the ACC scratchpad. The Pallas BlockSpec plays the same
    role for VMEM: the grid walks (M/BM, N/BN) output tiles; each step keeps a
    (BM, K) input strip, a (K, BN) weight strip and a (BM, BN) int32
    accumulator resident -- the same HBM<->scratchpad schedule VTA's LOAD/GEMM
    /STORE queues implement, with K kept whole because every layer in the
    paper fits (K <= 1152, strip <= BM*K = 144 KiB of int8).
  * Block sizes default to BM=128, BN=min(N,128): multiples of the MXU
    systolic tile in the M/N dims while keeping VTA's native block (16) as an
    exact divisor.
  * interpret=True is REQUIRED here: the artifacts run on the CPU PJRT plugin
    from rust, and real-TPU Pallas lowering emits Mosaic custom-calls that
    plugin cannot execute. Numerics are integer-exact either way.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VTA native GEMM block (paper Table 1: LOG_BLOCK=4 -> 16).
VTA_BLOCK = 16

DEFAULT_BM = 128
DEFAULT_BN = 128


def _gemm_kernel(x_ref, w_ref, o_ref, *, shift):
    """One (BM, BN) output tile: int8 strips -> int32 MXU dot -> requantize.

    Mirrors one VTA GEMM+ALU uop sequence: multiply-accumulate into the ACC
    scratchpad (int32), then the store path shifts and clips back to int8.
    """
    x = x_ref[...].astype(jnp.int32)  # (BM, K) int8 strip in VMEM
    w = w_ref[...].astype(jnp.int32)  # (K, BN) int8 strip in VMEM
    acc = jnp.dot(x, w, preferred_element_type=jnp.int32)  # ACC tile
    shifted = jax.lax.shift_right_arithmetic(acc, jnp.int32(shift))
    o_ref[...] = jnp.clip(shifted, -128, 127).astype(jnp.int8)


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("shift", "bm", "bn"))
def gemm_q(
    x_i8: jax.Array,  # (M, K) int8
    w_i8: jax.Array,  # (K, N) int8
    *,
    shift: int,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
) -> jax.Array:  # (M, N) int8
    """Quantized blocked GEMM via pallas_call; pads M/N up to block multiples."""
    m, k = x_i8.shape
    k2, n = w_i8.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bn = min(bn, _round_up(n, VTA_BLOCK))
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    if mp != m:
        x_i8 = jnp.pad(x_i8, ((0, mp - m), (0, 0)))
    if np_ != n:
        w_i8 = jnp.pad(w_i8, ((0, 0), (0, np_ - n)))
    grid = (mp // bm, np_ // bn)
    out = pl.pallas_call(
        functools.partial(_gemm_kernel, shift=shift),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int8),
        interpret=True,  # CPU-PJRT target; see module docstring
    )(x_i8, w_i8)
    return out[:m, :n]


def im2col(x_i8: jax.Array, *, kh: int, kw: int, pad: int, stride: int):
    """(H, W, C) -> (OH*OW, KH*KW*C) patch matrix, K ordered (kh, kw, c).

    This is the layout VTA's LOAD queue produces when staging input tiles for
    the GEMM core; the rust functional simulator uses the identical ordering.
    """
    h, w, c = x_i8.shape
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    xp = jnp.pad(x_i8, ((pad, pad), (pad, pad), (0, 0)))
    rows = []
    for i in range(kh):
        for j in range(kw):
            sl = xp[i : i + stride * (oh - 1) + 1 : stride,
                    j : j + stride * (ow - 1) + 1 : stride, :]
            rows.append(sl.reshape(oh * ow, c))
    return jnp.concatenate(rows, axis=1), (oh, ow)


def conv2d_q(
    x_i8: jax.Array,  # (H, W, C) int8
    w_i8: jax.Array,  # (KH, KW, C, KC) int8
    *,
    pad: int,
    stride: int,
    shift: int,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
) -> jax.Array:  # (OH, OW, KC) int8
    """VTA-semantics quantized conv2d = im2col + Pallas blocked GEMM."""
    kh, kw, c, kc = w_i8.shape
    patches, (oh, ow) = im2col(x_i8, kh=kh, kw=kw, pad=pad, stride=stride)
    wmat = w_i8.reshape(kh * kw * c, kc)
    out = gemm_q(patches, wmat, shift=shift, bm=bm, bn=bn)
    return out.reshape(oh, ow, kc)
