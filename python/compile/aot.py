"""AOT export: lower every ResNet18 conv layer graph to HLO text + manifest.

Run once by `make artifacts`:

    cd python && python -m compile.aot --out-dir ../artifacts

Interchange format is HLO *text*, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the rust crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the HLO text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Identical layer shapes are deduplicated (the paper's Table 2a repeats shapes:
conv6 == conv2, conv7 == conv9 == conv3, conv8 == conv10 == conv4); the
manifest maps every layer name to its artifact.
"""

import argparse
import hashlib
import json
import os

from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered_fn) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered_fn.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"shift": model.SHIFT, "layers": {}, "artifacts": {}}
    by_shape = {}
    for layer in model.RESNET18_LAYERS:
        key = layer.shape_key()
        if key not in by_shape:
            fname = f"{layer.name}.hlo.txt"
            text = to_hlo_text(model.lowered(layer.name))
            path = os.path.join(out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            by_shape[key] = fname
            manifest["artifacts"][fname] = {
                "shape_key": key,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                "bytes": len(text),
            }
            print(f"lowered {layer.name:8s} -> {fname} ({len(text)} chars)")
        manifest["layers"][layer.name] = {
            "artifact": by_shape[key],
            "h": layer.h, "w": layer.w, "c": layer.c,
            "kc": layer.kc, "kh": layer.kh, "kw": layer.kw,
            "oh": layer.oh, "ow": layer.ow,
            "pad": layer.pad, "stride": layer.stride,
            "shift": model.SHIFT,
        }
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}: {len(manifest['layers'])} layers, "
          f"{len(manifest['artifacts'])} unique artifacts")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    export_all(args.out_dir)


if __name__ == "__main__":
    main()
