"""L2: the per-layer compute graphs that get AOT-lowered for the rust runtime.

Each ResNet18 conv layer (paper Table 2a) becomes one jitted function

    (x: i32[H,W,C], w: i32[KH,KW,C,KC]) -> (y: i32[OH,OW,KC],)

wrapping the L1 Pallas kernel. The i32 boundary exists because the rust `xla`
crate (0.1.6) only exposes i32/i64/u32/u64/f32/f64 literals; values are always
int8-range, conversion is exact, and all internal arithmetic stays in the VTA
int8/int32 domain.

This module is build-time only: `aot.py` lowers it once into
`artifacts/*.hlo.txt` and rust never imports Python again.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from compile.kernels import vta_conv

# Global requantization shift used by every layer (and by the rust VTA
# functional simulator; keep in sync with rust/src/vta/config.rs).
SHIFT = 8


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    """One conv workload: paper Table 2(a) row."""

    name: str
    h: int
    w: int
    c: int
    kc: int
    kh: int
    kw: int
    oh: int
    ow: int
    pad: int
    stride: int

    @property
    def m(self) -> int:  # GEMM rows (output pixels)
        return self.oh * self.ow

    @property
    def k(self) -> int:  # GEMM contraction
        return self.kh * self.kw * self.c

    @property
    def n(self) -> int:  # GEMM cols (output channels)
        return self.kc

    def shape_key(self) -> str:
        """Unique key for artifact dedup (paper repeats several shapes)."""
        return (
            f"h{self.h}w{self.w}c{self.c}kc{self.kc}kh{self.kh}kw{self.kw}"
            f"p{self.pad}s{self.stride}"
        )


# Paper Table 2(a): the 10 profiled ResNet18 conv layers. Keep in sync with
# rust/src/workloads/resnet18.rs.
RESNET18_LAYERS = [
    ConvLayer("conv1", 56, 56, 64, 64, 3, 3, 56, 56, 1, 1),
    ConvLayer("conv2", 56, 56, 64, 128, 1, 1, 28, 28, 0, 2),
    ConvLayer("conv3", 56, 56, 64, 128, 3, 3, 28, 28, 1, 2),
    ConvLayer("conv4", 28, 28, 128, 128, 3, 3, 28, 28, 1, 1),
    ConvLayer("conv5", 28, 28, 128, 256, 1, 1, 14, 14, 0, 2),
    ConvLayer("conv6", 56, 56, 64, 128, 1, 1, 28, 28, 0, 2),
    ConvLayer("conv7", 56, 56, 64, 128, 3, 3, 28, 28, 1, 2),
    ConvLayer("conv8", 28, 28, 128, 128, 3, 3, 28, 28, 1, 1),
    ConvLayer("conv9", 56, 56, 64, 128, 3, 3, 28, 28, 1, 2),
    ConvLayer("conv10", 28, 28, 128, 128, 3, 3, 28, 28, 1, 1),
]


def layer_by_name(name: str) -> ConvLayer:
    for layer in RESNET18_LAYERS:
        if layer.name == name:
            return layer
    raise KeyError(name)


def conv_fn(layer: ConvLayer):
    """Build the AOT entry point for one layer (i32 boundary, 1-tuple out)."""

    def fn(x_i32, w_i32):
        x = x_i32.astype(jnp.int8)
        w = w_i32.astype(jnp.int8)
        y = vta_conv.conv2d_q(
            x, w, pad=layer.pad, stride=layer.stride, shift=SHIFT
        )
        return (y.astype(jnp.int32),)

    return fn


def example_args(layer: ConvLayer):
    """abstract args for jax.jit(...).lower()."""
    return (
        jax.ShapeDtypeStruct((layer.h, layer.w, layer.c), jnp.int32),
        jax.ShapeDtypeStruct((layer.kh, layer.kw, layer.c, layer.kc), jnp.int32),
    )


@functools.lru_cache(maxsize=None)
def lowered(name: str):
    layer = layer_by_name(name)
    return jax.jit(conv_fn(layer)).lower(*example_args(layer))
